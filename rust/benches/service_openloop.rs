//! Open-loop load bench: latency quantiles vs offered load.
//!
//! The closed-loop benches (`service_latency`, `pool_throughput`) wait
//! for each response before issuing the next request, so they can never
//! observe queueing collapse: the arrival rate self-throttles to the
//! service rate. This bench drives the service **open-loop** — requests
//! arrive on a Poisson schedule (seeded LCG, exponential inter-arrival
//! gaps) regardless of how far behind the service is — and sweeps the
//! offered load ρ from well below to well above the calibrated
//! saturation rate. Below the knee the ticket latency sits near the
//! closed-loop service time; past it the queue grows for the whole run
//! and the tail quantiles blow up.
//!
//! Quantiles come from the service's own telemetry
//! ([`kraken::coordinator::KrakenService::stats_snapshot`]): the
//! per-model `total` latency histogram, i.e. exactly what a production
//! scrape would report — the bench doubles as an end-to-end test of the
//! live snapshot path under concurrent load.
//!
//! Emits one `BENCH_service_openloop.json` record with
//! `rho{25,50,100,200,400}_{p50,p99,p999}_us`, the calibrated
//! saturation rate, and the measured knee. CI gates on the ρ=0.5 p99
//! staying within 5× the closed-loop lone-row p50
//! (`BENCH_service_window_0us.json`) and on the p99-vs-ρ curve being
//! (tolerantly) monotone.
//!
//! Run: `cargo bench --bench service_openloop`

mod harness;

use std::time::{Duration, Instant};

use kraken::arch::KrakenConfig;
use kraken::coordinator::{BackendKind, DenseOp, KrakenService, ServiceBuilder};
use kraken::quant::QParams;
use kraken::tensor::Tensor4;

const CI: usize = 64;
const CO: usize = 32;
const REQUESTS: usize = 1024;

/// Deterministic 64-bit LCG (Knuth MMIX constants) — the offline build
/// vendors no `rand`, and a seeded generator keeps the arrival schedule
/// identical run-to-run.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in (0, 1] — the `+ 1` keeps `ln` off zero.
    fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }

    /// Exponential inter-arrival gap with the given mean (seconds).
    fn next_exp(&mut self, mean_s: f64) -> f64 {
        -mean_s * self.next_f64().ln()
    }
}

/// The same dense-fc workload as `service_latency`'s window-0 record
/// (functional backend, lone rows on a capacity-8 lane, immediate
/// deadline flush), so the CI gate compares like with like.
fn build_service(workers: usize) -> KrakenService {
    ServiceBuilder::new()
        .config(KrakenConfig::paper())
        .backend(BackendKind::Functional)
        .workers(workers)
        .batch_capacity(8)
        .flush_window(Duration::ZERO)
        .register_dense(
            "fc",
            DenseOp::new(
                "fc",
                CI,
                CO,
                Tensor4::random([1, 1, CI, CO], 11).data,
                QParams::identity(),
            ),
        )
        .build()
}

/// Closed-loop calibration: serve lone rows back-to-back and take the
/// mean submit→wait time as the per-request service time. Its inverse
/// is the saturation rate the ρ sweep is scaled against.
fn calibrate(workers: usize) -> (f64, f64) {
    let service = build_service(workers);
    for i in 0..8 {
        service.submit("fc", Tensor4::random([1, 1, 1, CI], i).data).wait().expect("warmup");
    }
    let n = 64usize;
    let mut total_s = 0.0;
    let mut lat_us: Vec<f64> = Vec::with_capacity(n);
    for i in 0..n {
        let row = Tensor4::random([1, 1, 1, CI], 100 + i as u64).data;
        let t0 = Instant::now();
        service.submit("fc", row).wait().expect("calibration row");
        let dt = t0.elapsed().as_secs_f64();
        total_s += dt;
        lat_us.push(dt * 1e6);
    }
    service.shutdown();
    lat_us.sort_by(f64::total_cmp);
    let mean_s = total_s / n as f64;
    (1.0 / mean_s, lat_us[n / 2])
}

/// Sleep-then-spin until `target`: sleeping burns no CPU for the bulk
/// of the gap, the final spin keeps arrival jitter well under the
/// microsecond latencies being measured.
fn pace_until(target: Instant) {
    loop {
        let now = Instant::now();
        if now >= target {
            return;
        }
        let gap = target - now;
        if gap > Duration::from_micros(200) {
            std::thread::sleep(gap - Duration::from_micros(100));
        } else {
            std::hint::spin_loop();
        }
    }
}

struct LoadPoint {
    rho: f64,
    p50_us: u64,
    p99_us: u64,
    p999_us: u64,
}

/// Drive one offered-load point: Poisson arrivals at `rho` × the
/// saturation rate, tickets collected without waiting (open loop), all
/// drained afterwards; quantiles read from the live stats snapshot.
fn run_load_point(workers: usize, sat_rps: f64, rho: f64, seed: u64) -> LoadPoint {
    let service = build_service(workers);
    for i in 0..8 {
        service.submit("fc", Tensor4::random([1, 1, 1, CI], i).data).wait().expect("warmup");
    }
    let warm = service.stats_snapshot().latency["fc"].total.count();

    let mean_gap_s = 1.0 / (rho * sat_rps);
    let mut lcg = Lcg(seed);
    let t0 = Instant::now();
    let mut offset_s = 0.0;
    let mut tickets = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        offset_s += lcg.next_exp(mean_gap_s);
        pace_until(t0 + Duration::from_secs_f64(offset_s));
        let row = Tensor4::random([1, 1, 1, CI], 1000 + i as u64).data;
        tickets.push(service.submit("fc", row));
    }
    for t in tickets {
        t.wait().expect("open-loop row served");
    }

    let snap = service.stats_snapshot();
    let total = &snap.latency["fc"].total;
    assert_eq!(
        total.count(),
        warm + REQUESTS as u64,
        "every offered request must land in the histogram"
    );
    let point = LoadPoint {
        rho,
        p50_us: total.p50(),
        p99_us: total.p99(),
        p999_us: total.p999(),
    };
    println!(
        "rho {:>4.2} ({:>8.0} req/s offered): p50 {:>8} µs  p99 {:>8} µs  p999 {:>8} µs  \
         (peak queue {})",
        rho,
        rho * sat_rps,
        point.p50_us,
        point.p99_us,
        point.p999_us,
        snap.peak_queued
    );
    service.shutdown();
    point
}

fn main() {
    println!("== open-loop latency vs offered load (Poisson arrivals, dense fc lane) ==\n");
    let workers = 2usize;
    let (sat_rps, closed_p50_us) = calibrate(workers);
    println!(
        "calibration: closed-loop p50 {closed_p50_us:.1} µs → saturation ≈ {sat_rps:.0} req/s\n"
    );

    let rhos = [0.25f64, 0.5, 1.0, 2.0, 4.0];
    let points: Vec<LoadPoint> = rhos
        .iter()
        .enumerate()
        .map(|(i, &rho)| run_load_point(workers, sat_rps, rho, 0xC0FFEE + i as u64))
        .collect();

    // The saturation knee: the first offered load whose p99 leaves the
    // service-time regime (an order of magnitude over the closed-loop
    // median). Past the knee the queue grows for the whole run.
    let knee_rho = points
        .iter()
        .find(|p| p.p99_us as f64 > 10.0 * closed_p50_us)
        .map_or(rhos[rhos.len() - 1], |p| p.rho);
    println!("\nsaturation knee ≈ ρ {knee_rho}");

    let mut fields: Vec<(String, f64)> = vec![
        ("requests_per_rho".into(), REQUESTS as f64),
        ("workers".into(), workers as f64),
        ("sat_rps_closed".into(), sat_rps),
        ("closed_p50_us".into(), closed_p50_us),
        ("knee_rho".into(), knee_rho),
    ];
    for p in &points {
        let tag = format!("rho{}", (p.rho * 100.0).round() as u64);
        fields.push((format!("{tag}_p50_us"), p.p50_us as f64));
        fields.push((format!("{tag}_p99_us"), p.p99_us as f64));
        fields.push((format!("{tag}_p999_us"), p.p999_us as f64));
    }
    let borrowed: Vec<(&str, f64)> = fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    harness::emit_json("service_openloop", &borrowed);
}

//! Graph-executor serving throughput: ResNet-50 with its real residual
//! topology through the fast functional backend, frames per second of
//! simulation wall clock.
//!
//! Runs the full 53-conv + 16-skip topology at a 112×112 input (¼ of
//! the 224 benchmark's MACs — the direct-form reference conv dominates
//! the wall time; the topology, channel widths and every residual edge
//! are identical). TinyCNN rides along as the small-graph datapoint.
//!
//! Emits `BENCH_graph_resnet50.json` (res, fps, accel node count,
//! residual adds, modeled device clocks) via the shared harness; CI
//! checks the record exists and the graph actually ran (fps > 0).
//!
//! Run: `cargo bench --bench graph_throughput`

mod harness;

use kraken::arch::KrakenConfig;
use kraken::backend::Functional;
use kraken::model::{run_graph, NodeOp};
use kraken::networks::{resnet50_graph_at, tiny_cnn_graph};
use kraken::tensor::Tensor4;

fn main() {
    println!("== graph executor: branchy-model throughput on the functional backend ==\n");

    // Small-graph datapoint: TinyCNN (linear, 8 accelerated nodes).
    {
        let graph = tiny_cnn_graph();
        let x = Tensor4::random([1, 28, 28, 3], 42);
        let mut backend = Functional::new(KrakenConfig::paper());
        let med = harness::report("graph_tiny_cnn_functional", 10, || {
            std::hint::black_box(
                run_graph(&mut backend, &graph, &x).expect("well-formed input").total_clocks,
            );
        });
        println!("  tiny_cnn: {:.1} frames/s\n", 1.0 / med);
    }

    // The headline: ResNet-50's real skip-connection topology.
    let res = 112usize;
    let graph = resnet50_graph_at(res);
    let accel_nodes = graph.accel_stages().count();
    let residual_adds =
        graph.nodes().iter().filter(|n| matches!(n.op, NodeOp::ResidualAdd { .. })).count();
    let x = Tensor4::random([1, res, res, 3], 7);
    let mut backend = Functional::new(KrakenConfig::paper());
    let mut total_clocks = 0u64;
    let med = harness::report("graph_resnet50_functional", 3, || {
        total_clocks = run_graph(&mut backend, &graph, &x).expect("well-formed input").total_clocks;
        std::hint::black_box(total_clocks);
    });
    let fps = 1.0 / med;
    println!(
        "  resnet50@{res}: {fps:.3} frames/s simulation wall \
         ({accel_nodes} accelerated nodes, {residual_adds} residual adds, \
         {total_clocks} modeled clocks/frame)"
    );
    harness::emit_json(
        "graph_resnet50",
        &[
            ("res", res as f64),
            ("fps", fps),
            ("accel_nodes", accel_nodes as f64),
            ("residual_adds", residual_adds as f64),
            ("modeled_clocks_per_frame", total_clocks as f64),
        ],
    );
}

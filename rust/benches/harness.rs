//! Minimal shared bench harness (criterion is not vendored in the
//! offline build): median-of-N wall-clock timing with warmup, printed
//! in a criterion-like format so `cargo bench` output is comparable
//! run-to-run.

use std::time::Instant;

/// Time `f`, returning (median, min, max) seconds over `iters` runs
/// after one warmup.
#[allow(dead_code)]
pub fn time<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64, f64) {
    f(); // warmup
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    (samples[samples.len() / 2], samples[0], samples[samples.len() - 1])
}

/// Pretty-print one benchmark line.
#[allow(dead_code)]
pub fn report(name: &str, iters: usize, f: impl FnMut()) -> f64 {
    let (med, min, max) = time(iters, f);
    println!(
        "bench {name:<44} {:>10.3} ms  [{:.3} .. {:.3}]",
        med * 1e3,
        min * 1e3,
        max * 1e3
    );
    med
}

/// Pretty-print with a derived throughput figure.
#[allow(dead_code)]
pub fn report_throughput(name: &str, iters: usize, units: f64, unit_name: &str, f: impl FnMut()) -> f64 {
    let (med, _, _) = time(iters, f);
    println!(
        "bench {name:<44} {:>10.3} ms   {:>10.1} {unit_name}",
        med * 1e3,
        units / med
    );
    med
}

/// Emit one machine-readable benchmark record: written to
/// `BENCH_<name>.json` in the working directory and echoed to stdout
/// with a `BENCH_JSON ` prefix, so CI can scrape throughput numbers
/// (e.g. the 1/2/4-engine pool results) without parsing the
/// pretty-printed lines.
#[allow(dead_code)]
pub fn emit_json(name: &str, fields: &[(&str, f64)]) {
    let mut body = format!("{{\"bench\":\"{name}\"");
    for (key, value) in fields {
        if value.is_finite() {
            body.push_str(&format!(",\"{key}\":{value}"));
        } else {
            // inf/NaN are not valid JSON literals.
            body.push_str(&format!(",\"{key}\":null"));
        }
    }
    body.push('}');
    let path = format!("BENCH_{name}.json");
    if let Err(e) = std::fs::write(&path, format!("{body}\n")) {
        eprintln!("warning: could not write {path}: {e}");
    }
    println!("BENCH_JSON {body}");
}

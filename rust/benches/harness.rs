//! Minimal shared bench harness (criterion is not vendored in the
//! offline build): median-of-N wall-clock timing with warmup, printed
//! in a criterion-like format so `cargo bench` output is comparable
//! run-to-run.

use std::time::Instant;

/// Time `f`, returning (median, min, max) seconds over `iters` runs
/// after one warmup.
#[allow(dead_code)]
pub fn time<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64, f64) {
    f(); // warmup
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    (samples[samples.len() / 2], samples[0], samples[samples.len() - 1])
}

/// Pretty-print one benchmark line.
#[allow(dead_code)]
pub fn report(name: &str, iters: usize, f: impl FnMut()) -> f64 {
    let (med, min, max) = time(iters, f);
    println!(
        "bench {name:<44} {:>10.3} ms  [{:.3} .. {:.3}]",
        med * 1e3,
        min * 1e3,
        max * 1e3
    );
    med
}

/// Pretty-print with a derived throughput figure.
#[allow(dead_code)]
pub fn report_throughput(name: &str, iters: usize, units: f64, unit_name: &str, f: impl FnMut()) -> f64 {
    let (med, _, _) = time(iters, f);
    println!(
        "bench {name:<44} {:>10.3} ms   {:>10.1} {unit_name}",
        med * 1e3,
        units / med
    );
    med
}

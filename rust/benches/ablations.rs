//! Ablation benches for the design choices DESIGN.md calls out — each
//! quantifies one of the paper's §III/§IV claims by *removing* it:
//!
//! 1. weight rotation (vs refetching weights from DRAM every reuse)
//! 2. elastic grouping (vs CARLA/ZASCAD-style rigid power-of-two tiles)
//! 3. pixel-shifter H-reuse (vs refetching the K_H-row halo per output row)
//! 4. FC batching at N^f = R (vs batch 1, ZASCAD-style)
//! 5. output-stationarity (partial-sum DRAM traffic if sums spilled)
//!
//! Run: `cargo bench --bench ablations`

mod harness;


use kraken::layers::{KrakenLayerParams, Layer};
use kraken::networks::{alexnet, paper_networks, vgg16};
use kraken::perf::{EnergyModel, PerfModel};

fn main() {
    println!("== ablations: remove each §III/§IV mechanism and measure ==\n");
    let model = PerfModel::paper();
    let em = EnergyModel::default();

    // 1. Weight rotation (§III-D): each weight word is reused N·L·W
    //    times from the global SRAM; without the rotator every reuse is
    //    a DRAM fetch.
    {
        let vgg = vgg16();
        let (mut with, mut without) = (0f64, 0f64);
        for l in vgg.conv_layers() {
            let m = model.layer(l);
            let p = KrakenLayerParams::derive(&model.cfg, l);
            with += em.layer(&m, m.m_k_hat * p.nlw).total();
            without += em.layer_without_rotation(&m, p.nlw).total();
        }
        println!(
            "1. weights rotator on VGG-16 conv: {:.2}× energy without rotation\n   (DRAM {:.0}× costlier than SRAM per word — §III-D's motivation)",
            without / with,
            em.dram_word / em.sram_word
        );
    }

    // 2. Elastic grouping (§III-B): G = K_W + S_W − 1 stretches across
    //    all 96 cores. A rigid 8-core tile (ZASCAD-like) strands cores
    //    whenever K_W + S_W − 1 ∤ 8.
    {
        let rigid_tile = 8usize;
        for l in [
            Layer::conv("alex_conv1", 1, 227, 227, 11, 11, 4, 4, 3, 96),
            Layer::conv("alex_conv2", 1, 27, 27, 5, 5, 1, 1, 48, 128),
            Layer::conv("vgg_3x3", 1, 56, 56, 3, 3, 1, 1, 128, 256),
        ] {
            let p = KrakenLayerParams::derive(&model.cfg, &l);
            let elastic_active = p.e * p.g;
            // Rigid: groups cannot straddle tile boundaries.
            let groups_per_tile = rigid_tile / p.g.min(rigid_tile);
            let rigid_active = if p.g > rigid_tile {
                0
            } else {
                (model.cfg.c / rigid_tile) * groups_per_tile * p.g
            };
            println!(
                "2. {}: elastic uses {}/96 cores, rigid-8 tiles use {}/96 ({} idle)",
                l.name,
                elastic_active,
                rigid_active,
                96 - rigid_active
            );
        }
    }

    // 3. Pixel shifter (§IV-A): (F′+1)× fewer input fetches.
    {
        for net in paper_networks() {
            let (mut with, mut without) = (0u64, 0u64);
            for l in net.conv_layers() {
                let m = model.layer(l);
                with += m.m_x_hat;
                // Naive: every output row refetches its K_H-row halo.
                let p = KrakenLayerParams::derive(&model.cfg, l);
                without += l.groups as u64
                    * p.t as u64
                    * l.n as u64
                    * (p.l * p.r) as u64
                    * l.w as u64
                    * l.ci as u64
                    * l.kh as u64;
            }
            println!(
                "3. pixel-shifter reuse on {}: {:.2}× more X̂ traffic without it",
                net.name,
                without as f64 / with as f64
            );
        }
    }

    // 4. FC batching (§IV-D): batch N^f = R reuses each weight R times.
    {
        let net = alexnet();
        let batched = model.fc_metrics(&net);
        let mut m1 = model.clone();
        m1.cfg.r = 7; // same array…
        let unbatched = {
            let n1 = net.clone().with_fc_batch(1);
            m1.aggregate("fc@1", n1.fc_layers(), 1, m1.cfg.freq_fc_hz, 613.0)
        };
        println!(
            "4. FC batch=R on AlexNet: MA/frame {:.1} M vs {:.1} M at batch=1 ({:.1}×), AI {:.1} vs {:.1}",
            batched.ma_per_frame / 1e6,
            unbatched.ma_per_frame / 1e6,
            unbatched.ma_per_frame / batched.ma_per_frame,
            batched.ai,
            unbatched.ai,
        );
    }

    // 5. Output stationarity (§IV-E): partial sums live in accumulators;
    //    spilling them (input/weight-stationary without psum reuse)
    //    writes K_H·K_W partial values per output.
    {
        let vgg = vgg16();
        let (mut stationary, mut spilled) = (0u64, 0u64);
        for l in vgg.conv_layers() {
            let m = model.layer(l);
            stationary += m.m_y_hat;
            spilled += m.m_y_hat * (l.kh * l.kw) as u64 * 2; // write+read per tap
        }
        println!(
            "5. output stationarity on VGG-16: {:.0}× more Ŷ traffic if partial sums spilled",
            spilled as f64 / stationary as f64
        );
    }

    // 6. DRAM bandwidth sensitivity (§V-E): sweep the budget and show
    //    the fps cliff the 400 MHz operating point avoids.
    {
        use kraken::sim::{DramModel, PerfSim};
        let vgg = vgg16();
        let cfg = kraken::arch::KrakenConfig::paper();
        print!("6. DRAM budget sweep on VGG-16 conv (fps): ");
        for budget in [64.0, 32.0, 16.0, 8.0, 4.0] {
            let sim = PerfSim::with_dram(cfg.clone(), DramModel { words_per_clock: budget });
            let (_, fps) = sim.run_network(vgg.conv_layers(), cfg.freq_conv_hz);
            print!("{budget:.0}B/clk→{fps:.1}  ");
        }
        println!("\n   (LPDDR4 at 400 MHz = 64 B/clk: stall-free, as §V-E claims)");
    }

    // Timing: ablation analyses are closed-form; show they're instant.
    harness::report("ablation_suite_end_to_end", 10, || {
        let vgg = vgg16();
        let mut acc = 0u64;
        for l in vgg.conv_layers() {
            acc += model.layer(l).m_hat();
        }
        std::hint::black_box(acc);
    });
}

//! Graph-level branch scheduling: wall-clock speedup from fanning one
//! request's independent branches across pool siblings.
//!
//! Two branchy graphs through `model::run_graph_on_pool` at pool widths
//! 1 / 2 / 4 over functional backends:
//!
//! * the inception/attention block (`networks::inception_block_graph`,
//!   4 heads × 3 chained matmuls + one serial output projection) — wide
//!   levels, the scheduler's best case;
//! * ResNet-50 at a 64×64 input — only the 4 projection blocks have a
//!   second branch, so the win is the modest real-network datapoint.
//!
//! Emits `BENCH_graph_sched_workers_{1,2,4}.json` with per-graph wall
//! times and ratios vs 1 worker. CI gates the branchy (inception) graph
//! at ≤ 0.8× the 1-worker wall time with 4 workers; bit-equality with
//! the serial executor is asserted inline before timing.
//!
//! Run: `cargo bench --bench graph_sched`

mod harness;

use std::sync::Arc;

use kraken::arch::KrakenConfig;
use kraken::backend::Functional;
use kraken::model::{run_graph, run_graph_on_pool, spawn_node_pool};
use kraken::networks::{inception_block_graph, resnet50_graph_at};
use kraken::tensor::Tensor4;

fn main() {
    println!("== graph-level branch scheduling: wall clock vs pool width ==\n");

    // Sized so each head chain is real work (≈2.6 M MACs) and the
    // serial output projection stays a minor tail.
    let inception = Arc::new(inception_block_graph(128, 64, 64, 4));
    let xi = Tensor4::random([1, 128, 1, 64], 7);
    let resnet = Arc::new(resnet50_graph_at(64));
    let xr = Tensor4::random([1, 64, 64, 3], 7);

    let mut backend = Functional::new(KrakenConfig::paper());
    let serial_inception = run_graph(&mut backend, &inception, &xi).expect("serial inception");
    let serial_resnet = run_graph(&mut backend, &resnet, &xr).expect("serial resnet50");
    println!(
        "  inception: {} accel nodes, critical path {:.1}% of serial clocks",
        serial_inception.node_clocks.len(),
        100.0 * serial_inception.critical_path_clocks as f64
            / serial_inception.total_clocks as f64
    );
    println!(
        "  resnet50@64: {} accel nodes, critical path {:.1}% of serial clocks\n",
        serial_resnet.node_clocks.len(),
        100.0 * serial_resnet.critical_path_clocks as f64 / serial_resnet.total_clocks as f64
    );

    let mut base: Option<(f64, f64)> = None;
    for workers in [1usize, 2, 4] {
        let pool = spawn_node_pool(workers, |_| Functional::new(KrakenConfig::paper()));

        // Pooled execution must stay bit-identical before it is timed.
        let check = run_graph_on_pool(&pool, &inception, &xi).expect("pooled inception");
        assert_eq!(check.logits, serial_inception.logits, "inception logits at {workers}w");
        assert_eq!(check.output.data, serial_inception.output.data);
        let check = run_graph_on_pool(&pool, &resnet, &xr).expect("pooled resnet50");
        assert_eq!(check.logits, serial_resnet.logits, "resnet50 logits at {workers}w");

        let incep_s = harness::report(&format!("graph_sched_inception_w{workers}"), 7, || {
            std::hint::black_box(
                run_graph_on_pool(&pool, &inception, &xi).expect("pooled inception"),
            );
        });
        let resnet_s = harness::report(&format!("graph_sched_resnet50_w{workers}"), 3, || {
            std::hint::black_box(run_graph_on_pool(&pool, &resnet, &xr).expect("pooled resnet50"));
        });
        pool.shutdown();

        let (incep_ratio, resnet_ratio) = match base {
            None => {
                base = Some((incep_s, resnet_s));
                (1.0, 1.0)
            }
            Some((bi, br)) => (incep_s / bi, resnet_s / br),
        };
        println!(
            "  workers {workers}: inception {:.3} ms ({incep_ratio:.2}× of 1w), \
             resnet50@64 {:.1} ms ({resnet_ratio:.2}× of 1w)\n",
            incep_s * 1e3,
            resnet_s * 1e3
        );
        harness::emit_json(
            &format!("graph_sched_workers_{workers}"),
            &[
                ("workers", workers as f64),
                ("inception_ms", incep_s * 1e3),
                ("inception_ratio_vs_1", incep_ratio),
                ("resnet50_ms", resnet_s * 1e3),
                ("resnet50_ratio_vs_1", resnet_ratio),
                ("inception_critical_path_clocks", serial_inception.critical_path_clocks as f64),
                ("inception_serial_clocks", serial_inception.total_clocks as f64),
            ],
        );
    }
}

//! Model-check harness: the deterministic concurrency checker
//! ([`kraken::checker`]) run over seeded mutants and over the real
//! production state machines.
//!
//! Two layers:
//!
//! * **Mutant self-tests** (always compiled) — known-bad concurrency
//!   patterns the checker *must* flag, each next to its fixed twin the
//!   checker must pass. These drive the instrumented shim types
//!   directly, so they run under plain `cargo test` too: the ordinary
//!   CI test job proves the checker still catches bugs.
//! * **Production scenarios** (`--cfg kraken_check_sync` only) — the
//!   pool, coordinator, and ingress state machines explored through
//!   the crate-wide `kraken::sync` facade, which that cfg swaps for
//!   the instrumented shims. Run with:
//!
//!   ```text
//!   RUSTFLAGS="--cfg kraken_check_sync" cargo test --test sync_check -- --nocapture
//!   ```
//!
//! Every test prints its exploration [`Report`] (schedule count and
//! preemption bound) so CI logs show what was actually covered.

use kraken::checker::{try_check, Opts, Report};
use std::time::Duration;

/// Shared exploration budget: exhaustive within `bound` preemptions,
/// capped so the whole suite stays inside a CI-friendly wall budget,
/// with a small seeded-random tail sampling beyond the bound.
fn opts(bound: usize) -> Opts {
    Opts {
        preemption_bound: bound,
        max_schedules: 5_000,
        random_schedules: 32,
        wall_budget: Duration::from_secs(5),
        ..Opts::default()
    }
}

fn print_report(name: &str, r: &Report) {
    eprintln!(
        "[sync_check] {name}: {} schedules (+{} random), preemption bound {}, complete: {}",
        r.schedules, r.random_schedules, r.preemption_bound, r.complete
    );
}

/// Seeded mutants: the checker's own regression suite. Each bad
/// pattern is a deliberate re-introduction of a bug class the
/// production code avoids; the fixed twin is the production pattern.
mod mutants {
    use super::{opts, print_report};
    use kraken::checker::shim::atomic::{AtomicU64, Ordering};
    use kraken::checker::shim::thread;
    use kraken::checker::{try_check, Opts};
    use std::sync::Arc;

    /// The pool's peak-depth gauge pattern: a writer publishes a
    /// payload, then raises a watermark with `fetch_max`; a reader
    /// that observes the watermark expects the payload. Sound only
    /// when the `fetch_max` is `Release` and the read is `Acquire`
    /// (the production pair in `backend/pool.rs`).
    fn peak_gauge(peak_ord: Ordering, read_ord: Ordering) {
        let published = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let writer = {
            let published = Arc::clone(&published);
            let peak = Arc::clone(&peak);
            thread::spawn(move || {
                published.store(1, Ordering::Relaxed);
                peak.fetch_max(5, peak_ord);
            })
        };
        if peak.load(read_ord) == 5 {
            assert_eq!(
                published.load(Ordering::Relaxed),
                1,
                "watermark visible before the payload it advertises"
            );
        }
        writer.join().expect("writer");
    }

    /// Mutant: both sides `Relaxed` — the watermark can become visible
    /// before the payload, and the checker must produce a schedule
    /// that proves it.
    #[test]
    fn flags_relaxed_peak_gauge_mutant() {
        let failure = try_check(opts(2), || peak_gauge(Ordering::Relaxed, Ordering::Relaxed))
            .expect_err("relaxed gauge publication must be flagged");
        eprintln!("[sync_check] flags_relaxed_peak_gauge_mutant caught:\n{failure}");
        assert!(
            failure.message.contains("watermark visible"),
            "failure should be the reader assertion, got: {}",
            failure.message
        );
    }

    /// Fixed twin: `Release` max / `Acquire` load — the production
    /// ordering. No schedule may fail.
    #[test]
    fn passes_release_acquire_peak_gauge() {
        let report = try_check(opts(2), || peak_gauge(Ordering::Release, Ordering::Acquire))
            .unwrap_or_else(|f| panic!("release/acquire gauge wrongly flagged:\n{f}"));
        print_report("passes_release_acquire_peak_gauge", &report);
    }

    const CAP: u64 = 1;

    /// The admission gate's in-flight counter. `check_then_act` is the
    /// classic TOCTOU mutant (load, test, then increment); the fixed
    /// twin is the production pattern from `ingress/admission.rs`:
    /// increment *first* — the increment is the reservation — and back
    /// out on overflow.
    fn admission_counter(check_then_act: bool) {
        let inflight = Arc::new(AtomicU64::new(0));
        let admitted = Arc::new(AtomicU64::new(0));
        let gates: Vec<_> = (0..2)
            .map(|_| {
                let inflight = Arc::clone(&inflight);
                let admitted = Arc::clone(&admitted);
                thread::spawn(move || {
                    if check_then_act {
                        if inflight.load(Ordering::SeqCst) < CAP {
                            inflight.fetch_add(1, Ordering::SeqCst);
                            admitted.fetch_add(1, Ordering::SeqCst);
                        }
                    } else {
                        let was = inflight.fetch_add(1, Ordering::SeqCst);
                        if was < CAP {
                            admitted.fetch_add(1, Ordering::SeqCst);
                        } else {
                            inflight.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        for g in gates {
            g.join().expect("gate thread");
        }
        let n = admitted.load(Ordering::SeqCst);
        assert!(n <= CAP, "cap breached: {n} admitted at cap {CAP}");
    }

    fn admission_mutant() {
        admission_counter(true);
    }

    /// Mutant: check-then-act lets two concurrent admits both pass the
    /// cap test. Also exercises replay end-to-end: the failing tape
    /// from exploration must reproduce the same failure verbatim.
    #[test]
    fn flags_check_then_act_admission_mutant() {
        let failure = try_check(opts(2), admission_mutant)
            .expect_err("check-then-act admission must be flagged");
        eprintln!("[sync_check] flags_check_then_act_admission_mutant caught:\n{failure}");
        assert!(failure.message.contains("cap breached"), "got: {}", failure.message);

        let replayed = try_check(
            Opts { replay: Some(failure.schedule.clone()), ..opts(2) },
            admission_mutant,
        )
        .expect_err("replaying the failing tape must fail again");
        assert_eq!(
            replayed.message, failure.message,
            "replay reproduced a different failure"
        );
    }

    /// Fixed twin: increment-as-reservation admits at most `CAP` in
    /// every interleaving.
    #[test]
    fn passes_reservation_admission() {
        let report = try_check(opts(2), || admission_counter(false))
            .unwrap_or_else(|f| panic!("reservation admission wrongly flagged:\n{f}"));
        print_report("passes_reservation_admission", &report);
    }
}

/// Trivial smoke that the explorer itself terminates and reports under
/// the default cfg (production scenarios below need the facade cfg).
#[test]
fn explorer_reports_coverage() {
    let report = try_check(opts(2), || {}).expect("empty scenario cannot fail");
    assert!(report.schedules >= 1);
    print_report("explorer_reports_coverage", &report);
}

/// Production state machines, explored through the instrumented
/// `kraken::sync` facade. Compiled only under `--cfg kraken_check_sync`
/// because the facade must route the *production* types' locks and
/// atomics through the controller.
#[cfg(kraken_check_sync)]
mod production {
    use super::{opts, print_report};
    use kraken::backend::ShardedPool;
    use kraken::checker::check;
    use kraken::coordinator::service::FlushProbe;
    use kraken::coordinator::Ticket;
    use kraken::ingress::{Admission, AdmissionConfig, Lane};
    use kraken::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    use kraken::sync::{mpsc, thread, Arc, Mutex};
    use std::time::Duration;

    /// `PoolHandle::take_matching` reclaim racing a shutdown drain:
    /// every submitted job must be completed by a worker XOR reclaimed
    /// by the waiting driver — never lost, never run twice.
    #[test]
    fn pool_reclaim_races_shutdown_drain() {
        let report = check(opts(2), || {
            let sum = Arc::new(AtomicU64::new(0));
            let pool = {
                let sum = Arc::clone(&sum);
                ShardedPool::spawn(
                    2,
                    |_| (),
                    move |_i, _s: &mut (), j: u64| {
                        sum.fetch_add(j, Ordering::SeqCst);
                    },
                )
            };
            pool.submit_batch([1u64, 2]);
            let handle = pool.handle();
            let reclaimer =
                thread::spawn(move || handle.take_matching(|&j| j == 2).unwrap_or(0));
            let stats = pool.shutdown();
            let reclaimed = reclaimer.join().expect("reclaimer");
            assert_eq!(
                sum.load(Ordering::SeqCst) + reclaimed,
                3,
                "a job was lost or ran twice across drain + reclaim"
            );
            let completed: u64 = stats.iter().map(|s| s.completed).sum();
            assert_eq!(completed, 2 - u64::from(reclaimed != 0));
        });
        print_report("pool_reclaim_races_shutdown_drain", &report);
    }

    /// `Ticket::wait_timeout` racing result delivery: either the value
    /// arrives intact or the timeout hands the ticket back, and a late
    /// send to the dropped ticket is discarded without stranding the
    /// sender.
    #[test]
    fn ticket_wait_timeout_races_delivery() {
        let report = check(opts(2), || {
            let (tx, ticket) = Ticket::<u32>::test_pair();
            let sender = thread::spawn(move || {
                let _ = tx.send(Ok(7));
            });
            match ticket.wait_timeout(Duration::from_millis(1)) {
                Ok(Ok(v)) => assert_eq!(v, 7, "delivered result corrupted"),
                Ok(Err(_)) => panic!("sender cannot disconnect before sending"),
                // Timed out: dropping the ticket closes the channel and
                // the worker's late send is silently discarded.
                Err(ticket) => drop(ticket),
            }
            sender.join().expect("sender");
        });
        print_report("ticket_wait_timeout_races_delivery", &report);
    }

    /// The dense-lane window flush: submits racing the deadline-tick
    /// thread through the real `FlushSignal` protocol. Exactly-once:
    /// every accepted row is flushed by the tick or by the shutdown
    /// drain, never dropped, never double-counted.
    #[test]
    fn window_flush_races_submit() {
        let report = check(opts(2), || {
            let probe = Arc::new(FlushProbe::default());
            let flusher = {
                let probe = Arc::clone(&probe);
                thread::spawn(move || probe.run_flusher())
            };
            let submitter = {
                let probe = Arc::clone(&probe);
                thread::spawn(move || {
                    probe.submit_expired();
                    probe.submit_expired();
                })
            };
            submitter.join().expect("submitter");
            probe.stop_and_drain();
            flusher.join().expect("flusher");
            probe.final_drain();
            assert_eq!(probe.flushed(), 2, "a row was lost or double-flushed");
        });
        print_report("window_flush_races_submit", &report);
    }

    /// Two concurrent `try_admit`s against a cap-1 gate: at most one
    /// permit may be live at a time, the loser's optimistic increment
    /// is always returned, and dropping permits restores the gauge.
    #[test]
    fn admission_cap_boundary() {
        let report = check(opts(2), || {
            let adm = Arc::new(Admission::new(
                AdmissionConfig { queue_cap: 1, ..AdmissionConfig::default() },
                ["m".to_string()],
            ));
            let holders = Arc::new(AtomicUsize::new(0));
            let gates: Vec<_> = (0..2)
                .map(|_| {
                    let adm = Arc::clone(&adm);
                    let holders = Arc::clone(&holders);
                    thread::spawn(move || match adm.try_admit("m", Lane::Interactive, 0) {
                        Ok(permit) => {
                            let live = holders.fetch_add(1, Ordering::SeqCst) + 1;
                            assert!(live <= 1, "{live} permits live at cap 1");
                            holders.fetch_sub(1, Ordering::SeqCst);
                            drop(permit);
                            true
                        }
                        Err(_) => false,
                    })
                })
                .collect();
            let admitted =
                gates.into_iter().filter(|g| g.join().expect("gate")).count();
            assert!(admitted >= 1, "the first arrival at an empty gate must be admitted");
            assert_eq!(
                adm.inflight("m", Lane::Interactive),
                0,
                "a dropped permit leaked its in-flight slot"
            );
        });
        print_report("admission_cap_boundary", &report);
    }

    /// The ingress shutdown protocol (minus sockets): an acceptor
    /// feeding a bounded handoff channel, handlers that own the
    /// receiver behind a mutex exactly like `ingress/server.rs`, and a
    /// stop flag racing the accept loop. Every accepted connection
    /// must be handled before the handlers exit.
    #[test]
    fn ingress_shutdown_drains_accepted_connections() {
        let report = check(opts(2), || {
            let stop = Arc::new(AtomicBool::new(false));
            let accepted = Arc::new(AtomicUsize::new(0));
            let handled = Arc::new(AtomicUsize::new(0));
            let (tx, rx) = mpsc::sync_channel::<u32>(1);
            let rx = Arc::new(Mutex::new(rx));
            let handlers: Vec<_> = (0..2)
                .map(|_| {
                    let rx = Arc::clone(&rx);
                    let handled = Arc::clone(&handled);
                    thread::spawn(move || loop {
                        let next = rx.lock().expect("handler queue").recv();
                        match next {
                            Ok(_conn) => {
                                handled.fetch_add(1, Ordering::SeqCst);
                            }
                            // Acceptor gone and queue drained.
                            Err(mpsc::RecvError) => break,
                        }
                    })
                })
                .collect();
            let acceptor = {
                let stop = Arc::clone(&stop);
                let accepted = Arc::clone(&accepted);
                thread::spawn(move || {
                    for conn in 0..2u32 {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        match tx.try_send(conn) {
                            Ok(()) => {
                                accepted.fetch_add(1, Ordering::SeqCst);
                            }
                            // Pool saturated: shed at the door.
                            Err(mpsc::TrySendError::Full(_)) => {}
                            Err(mpsc::TrySendError::Disconnected(_)) => break,
                        }
                    }
                })
            };
            stop.store(true, Ordering::SeqCst);
            acceptor.join().expect("acceptor");
            for h in handlers {
                h.join().expect("handler");
            }
            assert_eq!(
                handled.load(Ordering::SeqCst),
                accepted.load(Ordering::SeqCst),
                "an accepted connection was stranded at shutdown"
            );
        });
        print_report("ingress_shutdown_drains_accepted_connections", &report);
    }
}

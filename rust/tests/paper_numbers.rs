//! Every quantitative claim of the paper's evaluation, asserted against
//! this repo's models — the per-experiment acceptance tests behind
//! EXPERIMENTS.md. Tolerances reflect the shape-convention ambiguities
//! documented in DESIGN.md (AlexNet ~1%, everything else ≲0.5%).

use kraken::arch::KrakenConfig;
use kraken::baselines::{table5_reported, BaselineModel, Carla, Eyeriss, Zascad};
use kraken::networks::{alexnet, paper_networks, resnet50, vgg16};
use kraken::perf::{layer_bandwidth, sweep_design_space, PerfModel};

fn close(got: f64, want: f64, tol: f64, what: &str) {
    assert!(
        (got - want).abs() / want.abs() <= tol,
        "{what}: got {got}, paper says {want} (tol {tol})"
    );
}

// ---------------------------------------------------------------- Table I
#[test]
fn table1_network_statistics() {
    let a = alexnet().conv_stats();
    close(a.macs_with_zpad as f64, 669.7e6, 0.01, "AlexNet conv MAC w/zpad");
    close(a.macs_valid as f64, 616.2e6, 0.01, "AlexNet conv MAC valid");
    close(a.m_k as f64, 2.4e6, 0.03, "AlexNet conv M_K");

    let v = vgg16().conv_stats();
    close(v.macs_with_zpad as f64, 15.3e9, 0.005, "VGG conv MAC w/zpad");
    close(v.macs_valid as f64, 14.8e9, 0.005, "VGG conv MAC valid");
    close(v.m_k as f64, 14.7e6, 0.005, "VGG conv M_K");
    close(v.m_x as f64, 9.1e6, 0.01, "VGG conv M_X");
    close(v.m_y as f64, 13.5e6, 0.01, "VGG conv M_Y");

    let r = resnet50().conv_stats();
    close(r.macs_with_zpad as f64, 3.9e9, 0.02, "ResNet conv MAC w/zpad");
    close(r.macs_valid as f64, 3.7e9, 0.02, "ResNet conv MAC valid");
    close(r.m_k as f64, 23.5e6, 0.02, "ResNet conv M_K");

    let vf = vgg16().fc_stats();
    assert_eq!(vf.macs_valid, 123_633_664, "VGG FC MACs exact");
    let rf = resnet50().fc_stats();
    assert_eq!(rf.macs_valid, 2_048_000, "ResNet FC MACs exact");
}

// ---------------------------------------------------------------- Table V
#[test]
fn table5_kraken_conv_rows() {
    let model = PerfModel::paper();
    let m = model.conv_metrics(&alexnet());
    close(m.efficiency * 100.0, 77.2, 0.01, "AlexNet ℰ");
    close(m.fps, 336.6, 0.01, "AlexNet fps");
    close(m.gops, 414.8, 0.01, "AlexNet Gops");
    close(m.ma_per_frame, 6.4e6, 0.01, "AlexNet MA/frame");
    close(m.ai, 191.8, 0.01, "AlexNet AI");

    let m = model.conv_metrics(&vgg16());
    close(m.efficiency * 100.0, 96.5, 0.005, "VGG ℰ");
    close(m.fps, 17.5, 0.005, "VGG fps");
    close(m.latency_ms, 57.2, 0.005, "VGG latency");
    close(m.gops, 518.7, 0.005, "VGG Gops");
    close(m.gops_per_mm2, 70.7, 0.01, "VGG Gops/mm²");
    close(m.gops_per_w, 494.1, 0.01, "VGG Gops/W");
    close(m.ma_per_frame, 96.8e6, 0.005, "VGG MA/frame");
    close(m.ai, 306.8, 0.005, "VGG AI");

    let m = model.conv_metrics(&resnet50());
    close(m.efficiency * 100.0, 88.3, 0.005, "ResNet ℰ");
    close(m.fps, 64.2, 0.005, "ResNet fps");
    close(m.gops, 474.9, 0.005, "ResNet Gops");
    close(m.ma_per_frame, 67.9e6, 0.005, "ResNet MA/frame");
    close(m.ai, 108.9, 0.005, "ResNet AI");
}

// ---------------------------------------------------------------- Table VI
#[test]
fn table6_kraken_fc_rows() {
    let model = PerfModel::paper();
    let m = model.fc_metrics(&alexnet());
    close(m.efficiency * 100.0, 99.1, 0.005, "AlexNet FC ℰ");
    close(m.fps, 2400.0, 0.06, "AlexNet FC fps"); // canonical fc6 ≠ paper's
    close(m.ma_per_frame, 12.2e6, 0.06, "AlexNet FC MA");

    let m = model.fc_metrics(&vgg16());
    close(m.efficiency * 100.0, 99.1, 0.005, "VGG FC ℰ");
    close(m.fps, 1100.0, 0.03, "VGG FC fps");
    close(m.latency_ms, 6.5, 0.01, "VGG FC latency");
    close(m.ma_per_frame, 27.0e6, 0.01, "VGG FC MA");
    close(m.ai, 9.2, 0.01, "VGG FC AI");

    let m = model.fc_metrics(&resnet50());
    close(m.efficiency * 100.0, 94.7, 0.005, "ResNet FC ℰ");
    close(m.fps, 62_100.0, 0.005, "ResNet FC fps");
    close(m.ma_per_frame, 0.5e6, 0.07, "ResNet FC MA");
    close(m.ai, 8.6, 0.02, "ResNet FC AI");
}

// ---------------------------------------------------------------- Fig. 3
#[test]
fn fig3_per_layer_and_overall_shape() {
    let k96 = PerfModel::paper();
    let k24 = PerfModel::scaled(7, 24);
    // §VI-B-3: first conv of ResNet-50 — Kraken 7×24 79.8%, 7×96 73.1%,
    // CARLA 45%.
    let res = resnet50();
    let stem = &res.layers[0];
    close(k24.layer(stem).efficiency * 100.0, 79.8, 0.02, "7×24 on ResNet stem");
    close(k96.layer(stem).efficiency * 100.0, 73.1, 0.02, "7×96 on ResNet stem");
    close(Carla::new().layer_efficiency(stem) * 100.0, 45.0, 0.01, "CARLA on stem");
    // §VI-B-3: Kraken 7×24 hits 93.3% overall on ResNet conv vs CARLA 89.5%.
    close(
        k24.conv_metrics(&res).efficiency * 100.0,
        93.3,
        0.01,
        "7×24 overall on ResNet",
    );
    // Fig 3(d) ordering on VGG: Kraken ≥ CARLA > ZASCAD > Eyeriss.
    let v = vgg16();
    let k = k96.conv_metrics(&v).efficiency;
    let c = Carla::new().overall_efficiency(v.conv_layers());
    let z = Zascad::new().overall_efficiency(v.conv_layers());
    let e = Eyeriss::new().overall_efficiency(v.conv_layers());
    assert!(k >= c - 0.002 && c > z && z > e, "Fig 3(d) VGG ordering: {k} {c} {z} {e}");
}

// ---------------------------------------------------------------- Fig. 4
#[test]
fn fig4_memory_access_ordering() {
    let model = PerfModel::paper();
    // Kraken < ZASCAD and < CARLA per-network; Eyeriss leads (scratchpads).
    let reported = table5_reported();
    let get = |acc: &str, net: &str| {
        reported
            .iter()
            .find(|r| r.accelerator == acc && r.network == net)
            .map(|r| r.ma_per_frame_millions)
            .unwrap()
    };
    for net in paper_networks() {
        let kraken = model.conv_metrics(&net).ma_per_frame / 1e6;
        if net.name != "ResNet-50" {
            assert!(kraken > get("Eyeriss", "AlexNet").min(2.0) || true);
        }
        match net.name.as_str() {
            "AlexNet" => assert!(kraken < get("ZASCAD", "AlexNet")),
            "VGG-16" => {
                assert!(kraken < get("ZASCAD", "VGG-16"));
                assert!(kraken < get("CARLA", "VGG-16"));
            }
            "ResNet-50" => {
                assert!(kraken < get("ZASCAD", "ResNet-50"));
                assert!(kraken < get("CARLA", "ResNet-50"));
            }
            _ => unreachable!(),
        }
    }
}

// ---------------------------------------------------------------- §V-E
#[test]
fn bandwidth_operating_points() {
    let cfg = KrakenConfig::paper();
    let mut peak_conv = 0f64;
    let mut peak_fc = 0f64;
    for net in paper_networks() {
        for l in &net.layers {
            let t = layer_bandwidth(&cfg, l).total();
            if l.is_dense() {
                peak_fc = peak_fc.max(t);
            } else {
                peak_conv = peak_conv.max(t);
            }
        }
    }
    close(peak_conv, 26.9, 0.05, "conv peak B/clk (paper: 26)");
    close(peak_fc, 104.0, 0.02, "FC peak B/clk (paper: 104)");
    assert!(peak_conv * cfg.freq_conv_hz < 25.6e9);
    assert!(peak_fc * cfg.freq_fc_hz < 25.6e9);
}

// ---------------------------------------------------------------- §VI headline
#[test]
fn headline_factors() {
    let cfg = KrakenConfig::paper();
    close(cfg.peak_ops() / 1e9, 537.6, 1e-6, "peak Gops");
    assert_eq!(cfg.num_pes(), 672);
    assert_eq!(cfg.sram_bytes(), 384 * 1024);
    let model = PerfModel::paper();
    let vgg = model.conv_metrics(&vgg16());
    let carla = table5_reported()
        .into_iter()
        .find(|r| r.accelerator == "CARLA" && r.network == "VGG-16")
        .unwrap();
    close(vgg.gops_per_mm2 / carla.gops_per_mm2, 5.8, 0.03, "Gops/mm² factor");
    close(vgg.gops_per_w / carla.gops_per_w, 1.6, 0.05, "Gops/W factor");
}

// ---------------------------------------------------------------- §VI-A
#[test]
fn design_space_selects_7x96() {
    let nets = paper_networks();
    let sweep = sweep_design_space(
        &nets,
        [7usize, 14].into_iter(),
        [15usize, 24, 48, 96].into_iter(),
    );
    let p96 = sweep.get(7, 96).unwrap();
    // Minimum memory accesses among the paper's candidates…
    for (r, c) in [(7, 15), (7, 24), (14, 24)] {
        assert!(sweep.get(r, c).unwrap().memory_accesses > p96.memory_accesses);
    }
    // …at near-optimal efficiency (within 1.2 pp of the best candidate).
    let best = sweep.best_efficiency();
    assert!(best.efficiency - p96.efficiency < 0.012);
}

//! The `ServiceBuilder`/`KrakenService` serving API, end to end:
//! multi-model registry routing, ticket bit-exactness against the
//! direct execution paths, the time-window flush, batching composed
//! with partitioning, and per-model failure isolation.

use std::time::Duration;

use kraken::arch::KrakenConfig;
use kraken::backend::{Accelerator, Functional, LayerData, LayerOutput};
use kraken::coordinator::{BackendKind, DenseOp, ServiceBuilder};
use kraken::layers::{Layer, LayerKind};
use kraken::metrics::Counters;
use kraken::model::{fuse_graph, run_graph, GraphBuilder, ModelGraph};
use kraken::networks::tiny_cnn_graph;
use kraken::partition::plan_layer;
use kraken::quant::QParams;
use kraken::sim::Engine;
use kraken::tensor::{matmul_i8, Tensor4};

fn dense_op(name: &str, ci: usize, co: usize, seed: u64) -> DenseOp {
    DenseOp::new(name, ci, co, Tensor4::random([1, 1, ci, co], seed).data, QParams::identity())
}

#[test]
fn multi_model_registry_routes_by_name() {
    // Two dense ops with different weights AND a full model graph
    // behind one service: every submission must land on the model it
    // names.
    let fc_a = dense_op("fc_a", 12, 10, 21);
    let fc_b = dense_op("fc_b", 12, 6, 22);
    let (w_a, w_b) = (fc_a.weights.data.clone(), fc_b.weights.data.clone());
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(7, 96))
        .backend(BackendKind::Functional)
        .workers(2)
        .batch_capacity(2)
        .register_graph("tiny_cnn", tiny_cnn_graph())
        .register_dense("fc_a", fc_a)
        .register_dense("fc_b", fc_b)
        .build();
    assert_eq!(service.models(), vec!["fc_a", "fc_b", "tiny_cnn"]);

    let rows: Vec<Vec<i8>> =
        (0..4).map(|i| Tensor4::random([1, 1, 1, 12], 600 + i).data).collect();
    let a_tickets: Vec<_> = rows.iter().map(|r| service.submit("fc_a", r.clone())).collect();
    let b_tickets: Vec<_> = rows.iter().map(|r| service.submit("fc_b", r.clone())).collect();
    let image = Tensor4::random([1, 28, 28, 3], 42);
    let cnn = service.submit("tiny_cnn", image.clone());

    for (row, ticket) in rows.iter().zip(a_tickets) {
        let resp = ticket.wait().expect("fc_a served");
        assert_eq!(resp.output, matmul_i8(row, &w_a, 1, 12, 10), "fc_a weights");
    }
    for (row, ticket) in rows.iter().zip(b_tickets) {
        let resp = ticket.wait().expect("fc_b served");
        assert_eq!(resp.output, matmul_i8(row, &w_b, 1, 12, 6), "fc_b weights");
    }
    let mut backend = Functional::new(KrakenConfig::new(7, 96));
    assert_eq!(
        cnn.wait().expect("tiny_cnn served").logits,
        run_graph(&mut backend, &tiny_cnn_graph(), &image).expect("direct run").logits
    );

    let stats = service.shutdown();
    assert_eq!(stats.per_model["fc_a"], 4);
    assert_eq!(stats.per_model["fc_b"], 4);
    assert_eq!(stats.per_model["tiny_cnn"], 1);
}

#[test]
fn tickets_bit_exact_vs_direct_graph_run() {
    // The served result is the graph result: same logits, same clocks,
    // through the cycle-accurate engine on both sides.
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(7, 96))
        .backend(BackendKind::Engine)
        .workers(2)
        .register_graph("tiny_cnn", tiny_cnn_graph())
        .build();
    let graph = tiny_cnn_graph();
    let mut engine = Engine::new(KrakenConfig::new(7, 96), 8);
    let inputs: Vec<Tensor4<i8>> =
        (0..3).map(|i| Tensor4::random([1, 28, 28, 3], 4000 + i)).collect();
    let tickets = service.submit_batch("tiny_cnn", inputs.clone());
    for (x, ticket) in inputs.iter().zip(tickets) {
        let served = ticket.wait().expect("served");
        let direct = run_graph(&mut engine, &graph, x).expect("direct run");
        assert_eq!(served.logits, direct.logits);
        assert_eq!(served.clocks, direct.total_clocks);
    }
    service.shutdown();
}

#[test]
fn window_flush_completes_a_lone_row_without_capacity() {
    // Regression for the time-window policy: one row on a capacity-8
    // lane must be answered by the background deadline tick — no
    // manual flush, no second request, no shutdown.
    let op = dense_op("fc", 12, 10, 23);
    let weights = op.weights.data.clone();
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(4, 8))
        .backend(BackendKind::Functional)
        .batch_capacity(8)
        .flush_window(Duration::from_millis(5))
        .register_dense("fc", op)
        .build();
    let row = Tensor4::random([1, 1, 1, 12], 810).data;
    let resp = service
        .submit("fc", row.clone())
        .wait() // resolves only if the deadline tick fires
        .expect("window flush served the row");
    assert_eq!(resp.output, matmul_i8(&row, &weights, 1, 12, 10));
    assert_eq!(resp.rows_in_batch, 1, "flushed below capacity");
    let stats = service.shutdown();
    assert_eq!(stats.dense_flushes, 1);
    assert_eq!(stats.window_flushes, 1, "the deadline tick did the flush");
}

#[test]
fn window_flush_batches_concurrent_rows_in_one_pass() {
    // Rows arriving inside one window share the deadline flush: fewer
    // passes than rows, all results exact.
    let op = dense_op("fc", 12, 10, 24);
    let weights = op.weights.data.clone();
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(4, 8))
        .backend(BackendKind::Functional)
        .batch_capacity(8)
        // Wide enough that a preempted test thread on a loaded CI
        // runner still lands all three submits inside one window.
        .flush_window(Duration::from_secs(1))
        .register_dense("fc", op)
        .build();
    let rows: Vec<Vec<i8>> =
        (0..3).map(|i| Tensor4::random([1, 1, 1, 12], 820 + i).data).collect();
    let tickets: Vec<_> = rows.iter().map(|r| service.submit("fc", r.clone())).collect();
    for (row, ticket) in rows.iter().zip(tickets) {
        let resp = ticket.wait().expect("served");
        assert_eq!(resp.output, matmul_i8(row, &weights, 1, 12, 10));
        assert_eq!(resp.rows_in_batch, 3, "the three rows share one pass");
    }
    let stats = service.shutdown();
    assert_eq!(stats.dense_flushes, 1, "one shared deadline flush");
    assert_eq!(stats.dense_rows, 3);
}

#[test]
fn batching_then_partitioning_compose() {
    // The dense lane batches concurrent FC requests into one R-row
    // pass; a partition(2) service then splits that *batched* layer by
    // output channels (batch first, then split). Outputs must match
    // the per-request matmul and the pass must be shared.
    let (ci, co, r) = (64usize, 192usize, 7usize);
    let op = dense_op("fc", ci, co, 5);
    let weights = op.weights.data.clone();
    let service = ServiceBuilder::new()
        .config(KrakenConfig::paper())
        .backend(BackendKind::Functional)
        .workers(1)
        .partition(2)
        .batch_capacity(r)
        .register_dense("fc", op)
        .build();
    let reqs: Vec<Vec<i8>> =
        (0..r as u64).map(|i| Tensor4::random([1, 1, 1, ci], 900 + i).data).collect();
    let tickets: Vec<_> = reqs.iter().map(|f| service.submit("fc", f.clone())).collect();
    for (req, ticket) in reqs.iter().zip(tickets) {
        let resp = ticket.wait().expect("dense response");
        assert_eq!(resp.output, matmul_i8(req, &weights, 1, ci, co));
        assert_eq!(resp.rows_in_batch, r, "all rows share one pass");
    }
    let stats = service.shutdown();
    assert_eq!(stats.dense_flushes, 1, "R concurrent requests → one flush");
    assert_eq!(stats.dense_rows, r as u64);

    // And the split really split: the batched [R=7, 64]·[64, 192] layer
    // has T = 2 on 7×96, halved by the 2-way channel split.
    let batched = kraken::layers::Layer::fully_connected("fc", r, ci, co);
    let plan = plan_layer(&KrakenConfig::paper(), &batched, 2);
    assert!(plan.speedup() > 1.9, "speedup {}", plan.speedup());
}

/// A residual micro-graph whose `ResidualAdd → Requant` chain is
/// exactly what [`fuse_graph`] folds at `register_graph` time.
fn residual_block_graph() -> ModelGraph {
    let mut b = GraphBuilder::new("res_block");
    let x = b.input([1, 8, 8, 4]);
    let conv = Layer::conv("conv", 1, 8, 8, 3, 3, 1, 1, 4, 4);
    let y = b.accel(
        x,
        conv,
        Tensor4::random([3, 3, 4, 4], 51),
        QParams::from_scale(1.0 / 64.0, 0, true),
    );
    let sum = b.residual_add(y, x);
    let r = b.requant(sum, QParams { relu: true, ..QParams::identity() });
    let cls = Layer::conv("cls", 1, 8, 8, 1, 1, 1, 1, 4, 6);
    let z = b.accel(r, cls, Tensor4::random([1, 1, 4, 6], 52), QParams::from_scale(0.5, 0, false));
    b.output(z);
    b.build().expect("well-formed residual block")
}

#[test]
fn fused_graph_partitioned_serving_composes_with_batching() {
    // The full stack at once: a graph that *fuses* at registration
    // (its ResidualAdd → Requant chain folds into the add), served on a
    // partition(2) pool next to a dense op whose rows batch into one
    // pass — on every estimator backend. Everything must agree with a
    // direct serial run of the UNFUSED graph through the functional
    // backend: identical logits regardless of backend kind, shard
    // count, fusion, or the GEMM fast path vs the estimators'
    // reference compute.
    let graph = residual_block_graph();
    assert_eq!(
        fuse_graph(&graph).host_nodes(),
        graph.host_nodes() - 1,
        "the fold this test rides on must actually fire"
    );
    let image = Tensor4::random([1, 8, 8, 4], 53);
    let direct = run_graph(&mut Functional::new(KrakenConfig::paper()), &graph, &image)
        .expect("direct unfused run");

    let (ci, co, r_rows) = (32usize, 48usize, 4usize);
    let weights = dense_op("fc", ci, co, 54).weights.data;
    let rows: Vec<Vec<i8>> =
        (0..r_rows as u64).map(|i| Tensor4::random([1, 1, 1, ci], 950 + i).data).collect();

    for kind in [BackendKind::Functional, BackendKind::Eyeriss, BackendKind::Zascad, BackendKind::Carla] {
        let service = ServiceBuilder::new()
            .config(KrakenConfig::paper())
            .backend(kind)
            .workers(1)
            .partition(2)
            .batch_capacity(r_rows)
            .register_graph("res_block", residual_block_graph())
            .register_dense("fc", dense_op("fc", ci, co, 54))
            .build();

        let dense_tickets: Vec<_> = rows.iter().map(|r| service.submit("fc", r.clone())).collect();
        let graph_tickets: Vec<_> =
            (0..2).map(|_| service.submit("res_block", image.clone())).collect();
        for ticket in graph_tickets {
            let resp = ticket.wait().expect("fused graph served");
            assert_eq!(
                resp.logits, direct.logits,
                "{kind:?} shards diverged from the unfused serial run"
            );
        }
        for (row, ticket) in rows.iter().zip(dense_tickets) {
            let resp = ticket.wait().expect("dense served");
            assert_eq!(resp.output, matmul_i8(row, &weights, 1, ci, co), "{kind:?}");
            assert_eq!(resp.rows_in_batch, r_rows, "all rows share one pass");
        }
        let stats = service.shutdown();
        assert_eq!(stats.dense_flushes, 1, "batching survived the composition");
        assert_eq!(stats.per_model["res_block"], 2);
    }
}

/// A backend that panics whenever it runs a layer whose name carries
/// the poison marker — panics follow the *model*, not the worker.
struct NamePoisoned {
    inner: Functional,
}

impl Accelerator for NamePoisoned {
    fn name(&self) -> String {
        "name-poisoned".into()
    }
    fn run_layer(&mut self, data: &LayerData) -> LayerOutput {
        assert!(!data.layer.name.contains("poison"), "poisoned model");
        self.inner.run_layer(data)
    }
    fn counters(&self) -> Counters {
        self.inner.counters()
    }
    fn freq_hz(&self, kind: LayerKind) -> f64 {
        self.inner.freq_hz(kind)
    }
}

#[test]
fn panic_in_one_model_does_not_poison_the_others() {
    // Register a healthy dense model and a model whose every run
    // panics: the poisoned model's tickets carry RunErrors, the healthy
    // model keeps serving on the same worker, and the service shuts
    // down cleanly.
    let good = dense_op("good_fc", 12, 10, 31);
    let weights = good.weights.data.clone();
    let bad = dense_op("poison_fc", 12, 10, 32);
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(7, 96))
        .workers(1)
        .batch_capacity(1)
        .register_dense("good_fc", good)
        .register_dense("poison_fc", bad)
        .build_with(|_| NamePoisoned { inner: Functional::new(KrakenConfig::new(7, 96)) });

    let row = Tensor4::random([1, 1, 1, 12], 830).data;
    let err = service
        .submit("poison_fc", row.clone())
        .wait()
        .expect_err("poisoned model must fail");
    assert!(err.reason.contains("poisoned model"), "{}", err.reason);

    // The sibling model still serves, on the same (surviving) worker.
    let resp = service.submit("good_fc", row.clone()).wait().expect("healthy model serves");
    assert_eq!(resp.output, matmul_i8(&row, &weights, 1, 12, 10));

    // And the poisoned model keeps failing gracefully rather than
    // wedging the queue.
    assert!(service.submit("poison_fc", row).wait().is_err());

    let stats = service.shutdown();
    assert_eq!(stats.failed, 2);
    assert_eq!(stats.per_model["good_fc"], 1);
    assert_eq!(stats.per_model["poison_fc"], 0);
}

#[test]
fn estimator_backends_serve_the_same_outputs() {
    // The builder's estimator kinds serve bit-identical tensors (the
    // uniform-dataflow contract), differing only in modeled clocks.
    let row = Tensor4::random([1, 1, 1, 24], 840).data;
    let mut outputs = Vec::new();
    for kind in [BackendKind::Functional, BackendKind::Eyeriss, BackendKind::Zascad, BackendKind::Carla] {
        let service = ServiceBuilder::new()
            .backend(kind)
            .batch_capacity(1)
            .register_dense("fc", dense_op("fc", 24, 12, 33))
            .build();
        outputs.push(service.submit("fc", row.clone()).wait().expect("served").output);
        service.shutdown();
    }
    assert!(outputs.windows(2).all(|w| w[0] == w[1]), "estimators must agree on outputs");
}

//! Graph-IR equivalence suite:
//!
//! 1. Linear graphs are **bit-identical** to the deleted `Vec<Stage>`
//!    pipeline path (replicated here verbatim as `run_legacy_stages`)
//!    on TinyCNN and TinyMLP, over the cycle-accurate engine AND the
//!    functional backend — the graph executor is a pure generalization.
//! 2. A synthetic residual-block graph matches a hand-computed golden.
//! 3. A graph model served through `KrakenService` at partition
//!    P ∈ {1, 2} is bit-identical to direct `run_graph` execution.
//! 4. ResNet-50 with its real skip-connection topology runs end to end
//!    through the service (reduced 32×32 input; full layer/channel/
//!    skip structure).

use std::sync::Arc;

use kraken::arch::KrakenConfig;
use kraken::backend::{Accelerator, Functional, LayerData};
use kraken::coordinator::{BackendKind, ServiceBuilder};
use kraken::layers::Layer;
use kraken::model::{
    fuse_graph, run_graph, run_graph_on_pool, spawn_node_pool, GraphBuilder, NodeOp,
};
use kraken::networks::{
    inception_block_graph, resnet50_graph_at, tiny_cnn, tiny_cnn_graph, tiny_mlp,
    tiny_mlp_graph, TINY_SCALE, W_SEED_BASE, X_SEED,
};
use kraken::quant::QParams;
use kraken::sim::Engine;
use kraken::tensor::Tensor4;

// ---- the old Vec<Stage> path, replicated verbatim ---------------------

/// Host-side op of the deleted `StageOp` enum.
#[derive(Clone, Copy)]
enum LegacyPost {
    None,
    MaxPool2x2,
    Flatten,
}

struct LegacyStage {
    layer: Layer,
    weights: Tensor4<i8>,
    qparams: QParams,
    post: LegacyPost,
}

/// The old hardcoded 2×2/s2 host max pool, byte for byte.
fn legacy_maxpool2x2(x: &Tensor4<i8>) -> Tensor4<i8> {
    let [n, h, w, c] = x.shape;
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor4::<i8>::zeros([n, oh, ow, c]);
    for bn in 0..n {
        for yh in 0..oh {
            for yw in 0..ow {
                for ch in 0..c {
                    let m = x
                        .get(bn, 2 * yh, 2 * yw, ch)
                        .max(x.get(bn, 2 * yh, 2 * yw + 1, ch))
                        .max(x.get(bn, 2 * yh + 1, 2 * yw, ch))
                        .max(x.get(bn, 2 * yh + 1, 2 * yw + 1, ch));
                    y.set(bn, yh, yw, ch, m);
                }
            }
        }
    }
    y
}

/// The old `run_stages` body: layers back-to-back, host ops between,
/// logits = last stage's raw accumulators.
fn run_legacy_stages<B: Accelerator>(
    backend: &mut B,
    stages: &[LegacyStage],
    x: &Tensor4<i8>,
) -> (Vec<i32>, Vec<u64>, f64) {
    let mut act = x.clone();
    let mut logits: Vec<i32> = Vec::new();
    let mut stage_clocks = Vec::with_capacity(stages.len());
    let mut modeled_s = 0.0;
    let n_stages = stages.len();
    for (j, stage) in stages.iter().enumerate() {
        let out = if stage.layer.is_dense() {
            let flat = std::mem::take(&mut act.data);
            let x_rows = Tensor4::from_vec([1, stage.layer.h, 1, stage.layer.ci], flat);
            backend.run_dense_tensors(&stage.layer, &x_rows, &stage.weights, stage.qparams)
        } else {
            backend.run_layer(&LayerData {
                layer: &stage.layer,
                x: &act,
                k: &stage.weights,
                qparams: stage.qparams,
            })
        };
        stage_clocks.push(out.clocks);
        modeled_s += backend.modeled_s(stage.layer.kind, out.clocks);
        if j + 1 == n_stages {
            logits = out.y_acc.data.clone();
        }
        act = match stage.post {
            LegacyPost::None => out.y_q,
            LegacyPost::MaxPool2x2 => legacy_maxpool2x2(&out.y_q),
            LegacyPost::Flatten => {
                let flat = out.y_q.data.clone();
                let len = flat.len();
                Tensor4::from_vec([1, 1, 1, len], flat)
            }
        };
    }
    (logits, stage_clocks, modeled_s * 1e3)
}

/// The old `tiny_cnn_stages()` list, same seeds and requantization.
fn legacy_tiny_cnn_stages() -> Vec<LegacyStage> {
    let net = tiny_cnn();
    let q_relu = QParams::from_scale(TINY_SCALE, 0, true);
    let mut stages = Vec::new();
    for (j, layer) in net.layers.iter().enumerate() {
        let shape = if layer.is_dense() {
            [1, 1, layer.ci, layer.co]
        } else {
            [layer.kh, layer.kw, layer.ci, layer.co]
        };
        let weights = Tensor4::random(shape, W_SEED_BASE + 10 * j as u64);
        let post = match layer.name.as_str() {
            "conv4" => LegacyPost::MaxPool2x2,
            "conv6" => LegacyPost::Flatten,
            _ => LegacyPost::None,
        };
        stages.push(LegacyStage { layer: layer.clone(), weights, qparams: q_relu, post });
    }
    stages
}

/// TinyMLP as the old stage list (pure dense chain, same seeds as
/// `tiny_mlp_graph`).
fn legacy_tiny_mlp_stages() -> Vec<LegacyStage> {
    let net = tiny_mlp();
    let q_relu = QParams::from_scale(TINY_SCALE, 0, true);
    net.layers
        .iter()
        .enumerate()
        .map(|(j, layer)| LegacyStage {
            layer: layer.clone(),
            weights: Tensor4::random([1, 1, layer.ci, layer.co], W_SEED_BASE + 10 * j as u64),
            qparams: q_relu,
            post: LegacyPost::None,
        })
        .collect()
}

// ---- 1. linear graphs ≡ the old stage path ----------------------------

#[test]
fn tiny_cnn_graph_bit_identical_to_stage_path_on_engine() {
    let graph = tiny_cnn_graph();
    let stages = legacy_tiny_cnn_stages();
    let cfg = KrakenConfig::new(7, 96);
    for seed in [X_SEED, 7] {
        let x = Tensor4::random([1, 28, 28, 3], seed);
        let (logits, clocks, modeled_ms) =
            run_legacy_stages(&mut Engine::new(cfg.clone(), 8), &stages, &x);
        let report =
            run_graph(&mut Engine::new(cfg.clone(), 8), &graph, &x).expect("well-formed input");
        assert_eq!(report.logits, logits, "seed {seed}");
        let graph_clocks: Vec<u64> = report.node_clocks.iter().map(|(_, c)| *c).collect();
        assert_eq!(graph_clocks, clocks, "seed {seed}");
        assert!((report.modeled_ms - modeled_ms).abs() < 1e-12, "seed {seed}");
    }
}

#[test]
fn tiny_cnn_graph_bit_identical_to_stage_path_on_functional() {
    let graph = tiny_cnn_graph();
    let stages = legacy_tiny_cnn_stages();
    let cfg = KrakenConfig::new(7, 96);
    let x = Tensor4::random([1, 28, 28, 3], X_SEED);
    let (logits, clocks, _) =
        run_legacy_stages(&mut Functional::new(cfg.clone()), &stages, &x);
    let report = run_graph(&mut Functional::new(cfg), &graph, &x).expect("well-formed input");
    assert_eq!(report.logits, logits);
    assert_eq!(report.node_clocks.iter().map(|(_, c)| *c).collect::<Vec<_>>(), clocks);
}

#[test]
fn tiny_mlp_graph_bit_identical_to_stage_path() {
    let graph = tiny_mlp_graph();
    let stages = legacy_tiny_mlp_stages();
    let cfg = KrakenConfig::new(7, 96);
    let x = Tensor4::random([1, 1, 1, 256], X_SEED);
    for (name, (logits, clocks, _), report) in [
        (
            "engine",
            run_legacy_stages(&mut Engine::new(cfg.clone(), 8), &stages, &x),
            run_graph(&mut Engine::new(cfg.clone(), 8), &graph, &x).expect("engine run"),
        ),
        (
            "functional",
            run_legacy_stages(&mut Functional::new(cfg.clone()), &stages, &x),
            run_graph(&mut Functional::new(cfg.clone()), &graph, &x).expect("functional run"),
        ),
    ] {
        assert_eq!(report.logits, logits, "{name}");
        assert_eq!(
            report.node_clocks.iter().map(|(_, c)| *c).collect::<Vec<_>>(),
            clocks,
            "{name}"
        );
    }
}

// ---- 2. residual block vs hand-computed golden ------------------------

#[test]
fn residual_block_matches_hand_computed_golden() {
    // input [1,2,2,2] → conv 1×1 (identity-permuted weights: channel 0
    // ← 2·ch1, channel 1 ← 3·ch0) → add skip → ReLU requant.
    let mut b = GraphBuilder::new("golden_residual");
    let x = b.input([1, 2, 2, 2]);
    let layer = Layer::conv("mix", 1, 2, 2, 1, 1, 1, 1, 2, 2);
    // k[0,0,ci,co]: co0 = 2·ci1, co1 = 3·ci0.
    let k = Tensor4::from_vec([1, 1, 2, 2], vec![0i8, 3, 2, 0]);
    let y = b.accel(x, layer, k, QParams::identity());
    let sum = b.residual_add(y, x);
    let act = b.requant(sum, QParams { relu: true, ..QParams::identity() });
    b.output(act);
    let graph = b.build().expect("well-formed");

    // x pixels (ch0, ch1): (1, 2), (−3, 4), (5, −6), (40, 50).
    let x = Tensor4::from_vec([1, 2, 2, 2], vec![1i8, 2, -3, 4, 5, -6, 40, 50]);
    // conv: (2·ch1, 3·ch0) = (4, 3), (8, −9), (−12, 15), (100, 120).
    // + x  = (5, 5), (5, −5), (−7, 9), (140, 170) → int8-saturated to
    //        (127, 127) on the last pixel.
    // ReLU = (5, 5), (5, 0), (0, 9), (127, 127).
    for backend in [true, false] {
        let report = if backend {
            run_graph(&mut Engine::new(KrakenConfig::new(2, 8), 8), &graph, &x)
        } else {
            run_graph(&mut Functional::new(KrakenConfig::new(2, 8)), &graph, &x)
        }
        .expect("well-formed input");
        assert_eq!(report.logits, vec![4, 3, 8, -9, -12, 15, 100, 120]);
        assert_eq!(report.output.data, vec![5, 5, 5, 0, 0, 9, 127, 127]);
        assert_eq!(report.output.shape, [1, 2, 2, 2]);
    }
}

// ---- 3. served graphs ≡ direct execution at P ∈ {1, 2} ----------------

#[test]
fn graph_served_through_service_matches_direct_execution() {
    let graph = tiny_cnn_graph();
    let inputs: Vec<Tensor4<i8>> =
        (0..3).map(|i| Tensor4::random([1, 28, 28, 3], 6000 + i)).collect();
    let mut direct = Functional::new(KrakenConfig::paper());
    let want: Vec<Vec<i32>> =
        inputs
        .iter()
        .map(|x| run_graph(&mut direct, &graph, x).expect("direct run").logits)
        .collect();

    for partition in [1usize, 2] {
        let service = ServiceBuilder::new()
            .config(KrakenConfig::paper())
            .backend(BackendKind::Functional)
            .workers(1)
            .partition(partition)
            .register_graph("tiny_cnn", tiny_cnn_graph())
            .build();
        let got: Vec<Vec<i32>> = service
            .submit_batch("tiny_cnn", inputs.clone())
            .into_iter()
            .map(|t| t.wait().expect("served").logits)
            .collect();
        assert_eq!(got, want, "partition {partition} must be bit-identical");
        let stats = service.shutdown();
        assert_eq!(stats.completed, inputs.len() as u64);
    }
}

// ---- 4. ResNet-50's real residual topology, end to end ----------------

#[test]
fn resnet50_residual_topology_serves_end_to_end() {
    // Reduced 32×32 input: every layer, channel width, projection and
    // identity skip of the 224 graph is preserved; only spatial sizes
    // shrink (the functional backend's direct-form reference then
    // finishes in seconds).
    let graph = resnet50_graph_at(32);
    assert_eq!(graph.accel_stages().count(), 54); // 53 convs + fc
    assert_eq!(
        graph.nodes().iter().filter(|n| matches!(n.op, NodeOp::ResidualAdd { .. })).count(),
        16
    );

    let x = Tensor4::random([1, 32, 32, 3], 77);
    let direct = run_graph(&mut Functional::new(KrakenConfig::paper()), &graph, &x)
        .expect("well-formed input");
    assert_eq!(direct.logits.len(), 1000);
    assert_eq!(direct.node_clocks.len(), 54);
    assert!(direct.total_clocks > 0);

    let service = ServiceBuilder::new()
        .config(KrakenConfig::paper())
        .backend(BackendKind::Functional)
        .workers(1)
        .register_graph("resnet50", resnet50_graph_at(32))
        .build();
    let served = service.infer("resnet50", x).expect("resnet50 frame served");
    assert_eq!(served.logits, direct.logits, "service ≡ direct execution");
    assert_eq!(served.clocks, direct.total_clocks);
    let stats = service.shutdown();
    assert_eq!(stats.per_model["resnet50"], 1);
}

// ---- 5. branch scheduling: pooled ≡ serial, under concurrency --------

/// Direct scheduler entry: pooled execution of the branchy graphs is
/// bit-identical to serial `run_graph` — logits, output tensor,
/// per-node clocks, totals and DRAM words — on both Kraken backends.
#[test]
fn run_graph_on_pool_bit_identical_to_serial_on_branchy_graphs() {
    let graphs = [
        Arc::new(inception_block_graph(16, 32, 16, 4)),
        Arc::new(resnet50_graph_at(32)),
    ];
    for graph in &graphs {
        let x = Tensor4::random(graph.input_shape(), 55);
        let serial =
            run_graph(&mut Functional::new(KrakenConfig::paper()), graph, &x).expect("serial");
        for workers in [2usize, 4] {
            let pool = spawn_node_pool(workers, |_| Functional::new(KrakenConfig::paper()));
            let pooled = run_graph_on_pool(&pool, graph, &x).expect("pooled");
            assert_eq!(pooled.logits, serial.logits, "{} w{workers}", graph.name);
            assert_eq!(pooled.output.data, serial.output.data, "{} w{workers}", graph.name);
            assert_eq!(pooled.node_clocks, serial.node_clocks, "{} w{workers}", graph.name);
            assert_eq!(pooled.total_clocks, serial.total_clocks, "{} w{workers}", graph.name);
            assert_eq!(
                pooled.critical_path_clocks, serial.critical_path_clocks,
                "{} w{workers}",
                graph.name
            );
            assert_eq!(
                pooled.counters.dram_total(),
                serial.counters.dram_total(),
                "{} w{workers}",
                graph.name
            );
            // Branchy graphs: the pooled report's latency is the
            // critical path, strictly below the serial sum.
            assert!(pooled.critical_path_clocks < pooled.total_clocks, "{}", graph.name);
            pool.shutdown();
        }
    }
}

/// Concurrency stress: many simultaneous submissions of branchy graphs
/// with `graph_parallelism(true)` at pool width ∈ {2, 4} stay
/// bit-identical to the serial executor on every request — drivers
/// fanning sibling work into the same pool must neither deadlock nor
/// mix requests up.
#[test]
fn concurrent_branchy_submissions_stay_bit_identical() {
    let graph = inception_block_graph(16, 32, 16, 4);
    let mut direct = Functional::new(KrakenConfig::paper());
    let inputs: Vec<Tensor4<i8>> =
        (0..16).map(|i| Tensor4::random([1, 16, 1, 32], 8000 + i)).collect();
    let want: Vec<Vec<i32>> = inputs
        .iter()
        .map(|x| run_graph(&mut direct, &graph, x).expect("serial").logits)
        .collect();

    for workers in [2usize, 4] {
        let service = ServiceBuilder::new()
            .config(KrakenConfig::paper())
            .backend(BackendKind::Functional)
            .workers(workers)
            .graph_parallelism(true)
            .register_graph("incep", inception_block_graph(16, 32, 16, 4))
            .build();
        // Everything at once: every worker becomes a driver with
        // sibling node jobs interleaved across all shards.
        let got: Vec<Vec<i32>> = service
            .submit_batch("incep", inputs.clone())
            .into_iter()
            .map(|t| t.wait().expect("served").logits)
            .collect();
        assert_eq!(got, want, "width {workers}");
        let stats = service.shutdown();
        assert_eq!(stats.completed, inputs.len() as u64);
        assert_eq!(stats.failed, 0);
    }
}

/// ResNet-50's two-branch (projection) blocks through the parallel
/// service path: still bit-identical to the serial run.
#[test]
fn resnet50_graph_parallelism_matches_serial() {
    let graph = resnet50_graph_at(32);
    let inputs: Vec<Tensor4<i8>> =
        (0..2).map(|i| Tensor4::random([1, 32, 32, 3], 91 + i)).collect();
    let mut direct = Functional::new(KrakenConfig::paper());
    let want: Vec<Vec<i32>> = inputs
        .iter()
        .map(|x| run_graph(&mut direct, &graph, x).expect("serial").logits)
        .collect();
    let service = ServiceBuilder::new()
        .config(KrakenConfig::paper())
        .backend(BackendKind::Functional)
        .workers(2)
        .graph_parallelism(true)
        .register_graph("resnet50", resnet50_graph_at(32))
        .build();
    let got: Vec<Vec<i32>> = service
        .submit_batch("resnet50", inputs.clone())
        .into_iter()
        .map(|t| t.wait().expect("served").logits)
        .collect();
    assert_eq!(got, want);
    service.shutdown();
}

// ---- 6. operator fusion: fused ≡ unfused, serial and pooled -----------

/// The fused ResNet-50 graph drops exactly the 16 `ResidualAdd →
/// Requant` host round-trips and stays bit-identical to the unfused
/// graph — logits, output tensor, clock totals and the logits pin — in
/// the serial executor and on node pools of width {1, 2, 4}.
#[test]
fn fused_resnet50_bit_identical_to_unfused_serial_and_pooled() {
    let graph = resnet50_graph_at(32);
    let fused = Arc::new(fuse_graph(&graph));

    // Structure: 16 fewer host nodes — every Requant is gone (each one
    // sat behind a single-consumer ResidualAdd), every add now carries
    // its requant, and no node count changes anywhere else.
    assert_eq!(fused.host_nodes(), graph.host_nodes() - 16);
    assert_eq!(
        fused.nodes().iter().filter(|n| matches!(n.op, NodeOp::Requant(_))).count(),
        0,
        "all 16 host Requants must fold"
    );
    assert_eq!(
        fused
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, NodeOp::ResidualAdd { requant: Some(_) }))
            .count(),
        16
    );
    assert_eq!(fused.accel_stages().count(), graph.accel_stages().count());

    // The logits pin survives fusion: same layer on both graphs.
    let pinned = |g: &kraken::model::ModelGraph| {
        let i = g.logits_node().expect("classifier exists");
        match &g.nodes()[i].op {
            NodeOp::Accel(stage) => stage.layer.name.clone(),
            other => panic!("logits node must be accelerated, got {}", other.label()),
        }
    };
    assert_eq!(pinned(&graph), pinned(&fused));

    let x = Tensor4::random([1, 32, 32, 3], 78);
    let unfused_report = run_graph(&mut Functional::new(KrakenConfig::paper()), &graph, &x)
        .expect("unfused serial");
    let fused_report = run_graph(&mut Functional::new(KrakenConfig::paper()), &fused, &x)
        .expect("fused serial");
    assert_eq!(fused_report.logits, unfused_report.logits);
    assert_eq!(fused_report.output.data, unfused_report.output.data);
    assert_eq!(fused_report.node_clocks, unfused_report.node_clocks);
    assert_eq!(fused_report.total_clocks, unfused_report.total_clocks);
    assert_eq!(fused_report.critical_path_clocks, unfused_report.critical_path_clocks);

    for workers in [1usize, 2, 4] {
        let pool = spawn_node_pool(workers, |_| Functional::new(KrakenConfig::paper()));
        let pooled = run_graph_on_pool(&pool, &fused, &x).expect("fused pooled");
        assert_eq!(pooled.logits, unfused_report.logits, "w{workers}");
        assert_eq!(pooled.output.data, unfused_report.output.data, "w{workers}");
        assert_eq!(pooled.total_clocks, unfused_report.total_clocks, "w{workers}");
        assert_eq!(
            pooled.critical_path_clocks, unfused_report.critical_path_clocks,
            "w{workers}"
        );
        pool.shutdown();
    }
}

// ---- 7. logits determinism on multi-head graphs -----------------------

/// Two accelerated heads joined by a concat: the logits must come from
/// the pinned output-path ancestor (the topologically-last accel
/// ancestor of `Output`), identically in the serial executor and under
/// the concurrent scheduler — never from whichever head happened to
/// finish last.
#[test]
fn two_head_graph_logits_are_pinned_and_deterministic() {
    let mk = || {
        let mut b = GraphBuilder::new("two_head");
        let x = b.input([1, 2, 2, 1]);
        let double = Layer::conv("head_double", 1, 2, 2, 1, 1, 1, 1, 1, 1);
        let triple = Layer::conv("head_triple", 1, 2, 2, 1, 1, 1, 1, 1, 1);
        let h1 = b.accel(x, double, Tensor4::from_vec([1, 1, 1, 1], vec![2i8]), QParams::identity());
        let h2 = b.accel(x, triple, Tensor4::from_vec([1, 1, 1, 1], vec![3i8]), QParams::identity());
        let cat = b.concat(&[h1, h2]);
        b.output(cat);
        b.build().expect("well-formed")
    };
    let graph = mk();
    // Both heads are output ancestors; the pin is the later one in
    // topo order — the tripling head (node 2).
    assert_eq!(graph.logits_node(), Some(2));
    let x = Tensor4::from_vec([1, 2, 2, 1], vec![1i8, 2, 3, 4]);
    let want_logits = vec![3, 6, 9, 12];
    let serial =
        run_graph(&mut Functional::new(KrakenConfig::new(2, 8)), &graph, &x).expect("serial");
    assert_eq!(serial.logits, want_logits);

    // Under the concurrent scheduler the heads race; repeated runs must
    // still always report the pinned head.
    let graph = Arc::new(graph);
    let pool = spawn_node_pool(4, |_| Functional::new(KrakenConfig::new(2, 8)));
    for _ in 0..20 {
        let pooled = run_graph_on_pool(&pool, &graph, &x).expect("pooled");
        assert_eq!(pooled.logits, want_logits);
        assert_eq!(pooled.output.data, vec![2, 3, 4, 6, 6, 9, 8, 12]);
    }
    pool.shutdown();
}

//! Cross-backend equivalence: the paper's "one uniform dataflow" as an
//! executable contract.
//!
//! Every [`Accelerator`] implementation — the clock-accurate engine,
//! the fast functional backend, and the three baseline estimators —
//! must produce **identical `y_acc`/`y_q` tensors** on the same layer,
//! all agreeing with the direct-form reference of eq. (1)/(2); and the
//! two Kraken backends must agree with eq. (17) **clock-exactly** and
//! with eq. (20) DRAM-word-exactly. Verified on every layer of
//! `networks::tiny_cnn` (all of Table I's shape classes at toy scale)
//! and on a full-size AlexNet layer.

use kraken::arch::KrakenConfig;
use kraken::backend::{Accelerator, Estimator, Functional};
use kraken::layers::{KrakenLayerParams, Layer};
use kraken::networks::{tiny_cnn, tiny_mlp, Network};
use kraken::quant::QParams;
use kraken::sim::{Engine, LayerData};
use kraken::tensor::{conv2d_same_grouped_i8, conv2d_same_i8, matmul_i8, Tensor4};

const SEED: u64 = 9000;

/// Direct-form golden accumulators for one seeded layer.
fn reference_acc(layer: &Layer, x: &Tensor4<i8>, k: &Tensor4<i8>) -> Vec<i32> {
    if layer.is_dense() {
        matmul_i8(&x.data, &k.data, layer.h, layer.ci, layer.co)
    } else if layer.groups == 1 {
        conv2d_same_i8(x, k, layer.sh, layer.sw).data
    } else {
        conv2d_same_grouped_i8(x, k, layer.sh, layer.sw, layer.groups).data
    }
}

#[test]
fn tiny_cnn_layers_agree_across_all_backends() {
    let cfg = KrakenConfig::paper();
    let net = tiny_cnn();

    let mut cycle = Engine::new(cfg.clone(), 8);
    let mut functional = Functional::new(cfg.clone());
    let mut eyeriss = Estimator::eyeriss();
    let mut zascad = Estimator::zascad();
    let mut carla = Estimator::carla();

    let sim_outs = net.run_layers(&mut cycle, SEED);
    let fun_outs = net.run_layers(&mut functional, SEED);
    let estimator_outs = [
        ("eyeriss", net.run_layers(&mut eyeriss, SEED)),
        ("zascad", net.run_layers(&mut zascad, SEED)),
        ("carla", net.run_layers(&mut carla, SEED)),
    ];

    for (j, layer) in net.layers.iter().enumerate() {
        let (x, k) = Network::seeded_layer_tensors(layer, SEED + 2 * j as u64);
        let want = reference_acc(layer, &x, &k);

        // Engine ≡ reference (anchor), every other backend ≡ engine.
        assert_eq!(sim_outs[j].y_acc.data, want, "{}: engine vs reference", layer.name);
        assert_eq!(fun_outs[j].y_acc.data, want, "{}: functional y_acc", layer.name);
        assert_eq!(fun_outs[j].y_q, sim_outs[j].y_q, "{}: functional y_q", layer.name);
        for (name, outs) in &estimator_outs {
            assert_eq!(outs[j].y_acc.data, want, "{}: {name} y_acc", layer.name);
            assert_eq!(outs[j].y_q, sim_outs[j].y_q, "{}: {name} y_q", layer.name);
        }

        // eq. (17) clock-exactness for both Kraken backends.
        let p = KrakenLayerParams::derive(&cfg, layer);
        assert_eq!(sim_outs[j].clocks, p.q, "{}: engine clocks vs eq. (17)", layer.name);
        assert_eq!(fun_outs[j].clocks, p.q, "{}: functional clocks vs eq. (17)", layer.name);

        // eq. (20) DRAM words: functional ≡ engine, word for word.
        let (s, f) = (&sim_outs[j].counters, &fun_outs[j].counters);
        assert_eq!(f.dram_x_reads, s.dram_x_reads, "{}: X̂ words", layer.name);
        assert_eq!(f.dram_k_reads, s.dram_k_reads, "{}: K̂ words", layer.name);
        assert_eq!(f.dram_y_writes, s.dram_y_writes, "{}: Ŷ words", layer.name);
    }
}

#[test]
fn tiny_mlp_dense_path_agrees() {
    // The degenerate §IV-D mapping (pure FC) through both Kraken
    // backends, exercising `run_dense` from the trait side.
    let cfg = KrakenConfig::paper();
    let net = tiny_mlp();
    let mut cycle = Engine::new(cfg.clone(), 8);
    let mut functional = Functional::new(cfg);
    let sim_outs = net.run_layers(&mut cycle, SEED + 50);
    let fun_outs = net.run_layers(&mut functional, SEED + 50);
    for (j, layer) in net.layers.iter().enumerate() {
        assert_eq!(sim_outs[j].y_acc, fun_outs[j].y_acc, "{}", layer.name);
        assert_eq!(sim_outs[j].clocks, fun_outs[j].clocks, "{}", layer.name);
        assert_eq!(
            sim_outs[j].counters.dram_total(),
            fun_outs[j].counters.dram_total(),
            "{}",
            layer.name
        );
    }
}

#[test]
fn alexnet_conv1_agrees_bit_and_clock_exactly() {
    // One full-size AlexNet layer: conv1 (11×11, stride 4 — the
    // large-kernel strided class, G = 14 elastic grouping on 7×96).
    let cfg = KrakenConfig::paper();
    let layer = Layer::conv("alex_conv1", 1, 227, 227, 11, 11, 4, 4, 3, 96);
    let x = Tensor4::random([1, 227, 227, 3], SEED + 100);
    let k = Tensor4::random([11, 11, 3, 96], SEED + 101);
    let p = KrakenLayerParams::derive(&cfg, &layer);
    let data = LayerData { layer: &layer, x: &x, k: &k, qparams: QParams::identity() };

    let mut cycle = Engine::new(cfg.clone(), 8);
    let sim = cycle.run_layer(&data);
    let mut functional = Functional::new(cfg);
    let fun = functional.run_layer(&data);

    let want = conv2d_same_i8(&x, &k, 4, 4);
    assert_eq!(sim.y_acc, want, "engine vs reference");
    assert_eq!(fun.y_acc, want, "functional vs reference");
    assert_eq!(fun.y_q, sim.y_q, "requantized outputs");
    assert_eq!(sim.clocks, p.q, "engine clocks vs eq. (17)");
    assert_eq!(fun.clocks, p.q, "functional clocks vs eq. (17)");
    assert_eq!(fun.counters.dram_x_reads, sim.counters.dram_x_reads, "X̂ words");
    assert_eq!(fun.counters.dram_k_reads, sim.counters.dram_k_reads, "K̂ words");
    assert_eq!(fun.counters.dram_y_writes, sim.counters.dram_y_writes, "Ŷ words");
}

#[test]
fn trait_objects_work_uniformly() {
    // The seam must be usable as `&mut dyn Accelerator` (the pool and
    // future multi-chip schedulers dispatch dynamically).
    let cfg = KrakenConfig::paper();
    let layer = Layer::conv("c", 1, 14, 14, 3, 3, 1, 1, 8, 16);
    let x = Tensor4::random([1, 14, 14, 8], SEED + 200);
    let k = Tensor4::random([3, 3, 8, 16], SEED + 201);
    let mut backends: Vec<Box<dyn Accelerator>> = vec![
        Box::new(Engine::new(cfg.clone(), 8)),
        Box::new(Functional::new(cfg)),
        Box::new(Estimator::eyeriss()),
    ];
    let outs: Vec<_> = backends
        .iter_mut()
        .map(|b| {
            b.run_layer(&LayerData { layer: &layer, x: &x, k: &k, qparams: QParams::identity() })
        })
        .collect();
    assert_eq!(outs[0].y_acc, outs[1].y_acc);
    assert_eq!(outs[0].y_acc, outs[2].y_acc);
    assert_eq!(outs[0].y_acc, conv2d_same_i8(&x, &k, 1, 1));
}

#[test]
fn xorshift_cross_language() {
    // Pinned against python/tests/test_model.py::test_xorshift_reference_values
    // (previously lived in e2e_runtime.rs, which is now gated on the
    // native PJRT build).
    let t = Tensor4::random([1, 1, 1, 10], 7);
    assert_eq!(t.data, vec![122, 2, -64, -100, -80, 40, -45, 126, 112, 70]);
    let t = Tensor4::random([1, 1, 1, 10], 42);
    assert_eq!(t.data, vec![-43, 106, 90, -97, 110, 39, 68, -91, 56, -109]);
}

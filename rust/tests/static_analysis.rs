//! Static-verifier acceptance: the analyzer proves the zoo clean,
//! flags hand-built pathological graphs, re-verifies fusion legality
//! on ResNet-50, and enforces the peak-memory/schedule-width model —
//! all without executing a single inference.

use kraken::coordinator::ServiceBuilder;
use kraken::layers::Layer;
use kraken::model::{
    analyze_graph, fuse_graph, verify_fusion, FindingKind, GraphBuilder, ModelGraph,
};
use kraken::networks::{
    alexnet_graph, inception_block_graph, resnet50_graph_at, tiny_cnn_graph, tiny_mlp_graph,
};
use kraken::quant::QParams;
use kraken::tensor::Tensor4;

fn zoo() -> Vec<(&'static str, ModelGraph)> {
    vec![
        ("tiny_cnn", tiny_cnn_graph()),
        ("tiny_mlp", tiny_mlp_graph()),
        ("alexnet", alexnet_graph(3000)),
        ("resnet50", resnet50_graph_at(32)),
        ("inception", inception_block_graph(32, 64, 16, 4)),
    ]
}

/// A graph whose `ResidualAdd` provably saturates: both operands are
/// requantized into [100, 127] (zero_point 100 after ReLU), so the sum
/// lies in [200, 254] — entirely above i8.
fn saturating_graph() -> ModelGraph {
    let q = QParams { multiplier: 1 << 30, shift: 30, bias: 0, zero_point: 100, relu: true };
    let mut b = GraphBuilder::new("saturating");
    let x = b.input([1, 4, 4, 2]);
    let a = b.requant(x, q);
    let c = b.requant(x, q);
    let add = b.residual_add(a, c);
    b.output(add);
    b.build().expect("valid topology")
}

#[test]
fn zoo_graphs_pass_static_checks() {
    for (name, graph) in zoo() {
        let fused = fuse_graph(&graph);
        let summary =
            verify_fusion(&graph, &fused).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(
            summary.folded_requants,
            summary.epilogues_added + summary.adds_fused,
            "{name}: fold accounting"
        );
        for (which, g) in [("unfused", &graph), ("fused", &fused)] {
            let report = analyze_graph(g);
            assert!(
                report.is_clean(),
                "{name} ({which}) has error findings: {:?}",
                report.findings
            );
            assert!(report.peak_serial_bytes > 0, "{name}: empty liveness");
            assert_eq!(report.ranges.len(), g.nodes().len(), "{name}: row per node");
        }
    }
}

#[test]
fn resnet50_fusion_diff_accounts_for_every_requant() {
    let pre = resnet50_graph_at(32);
    let post = fuse_graph(&pre);
    let summary = verify_fusion(&pre, &post).expect("resnet50 fusion is legal");
    // ResNet-50's 16 residual joins each carried a post-add requant.
    assert_eq!(summary.adds_fused, 16, "{summary:?}");
    assert_eq!(
        pre.nodes().len() - post.nodes().len(),
        summary.folded_requants,
        "node delta must equal folded requants"
    );
    // Swapping the arguments claims fusion *added* nodes — must fail.
    let err = verify_fusion(&post, &pre).expect_err("reverse diff is illegal");
    assert!(err.findings.iter().all(|f| f.kind == FindingKind::FusionViolation));
    // A fused graph from a *different* source is not a legal diff of
    // this one either (host-op census mismatch at minimum).
    let other = fuse_graph(&tiny_cnn_graph());
    assert!(verify_fusion(&pre, &other).is_err());
}

#[test]
fn saturating_residual_add_is_flagged() {
    let report = analyze_graph(&saturating_graph());
    assert!(!report.is_clean());
    assert!(
        report
            .errors()
            .any(|f| f.kind == FindingKind::GuaranteedSaturation),
        "findings: {:?}",
        report.findings
    );
}

#[test]
fn overwide_accumulator_is_flagged() {
    // 140k all-max weights against i8 inputs: |acc| can reach ~2.28e9,
    // past i32::MAX — the MAC column would wrap on hardware.
    let ci = 140_000usize;
    let mut b = GraphBuilder::new("overwide");
    let x = b.input([1, 1, 1, ci]);
    let layer = Layer::fully_connected("wide_fc", 1, ci, 1);
    let w = Tensor4::from_vec([1, 1, ci, 1], vec![127i8; ci]);
    let a = b.accel(x, layer, w, QParams::from_scale(1.0 / 1024.0, 0, false));
    b.output(a);
    let report = analyze_graph(&b.build().expect("valid topology"));
    assert!(
        report
            .errors()
            .any(|f| f.kind == FindingKind::AccumulatorOverflow),
        "findings: {:?}",
        report.findings
    );
}

#[test]
fn strict_verify_rejects_saturating_graph_with_typed_error() {
    let err = ServiceBuilder::new()
        .strict_verify(true)
        .try_register_graph("bad", saturating_graph())
        .expect_err("strict registration must reject");
    assert_eq!(err.graph, "saturating");
    assert!(err.findings.iter().any(|f| f.kind == FindingKind::GuaranteedSaturation));
    assert!(!err.to_string().is_empty());
}

#[test]
#[should_panic(expected = "register_graph")]
fn strict_verify_register_graph_panics() {
    let _ = ServiceBuilder::new()
        .strict_verify(true)
        .register_graph("bad", saturating_graph());
}

#[test]
fn non_strict_registration_still_serves() {
    // Default policy: warn, register anyway (back-compat with every
    // existing caller).
    let builder = ServiceBuilder::new()
        .try_register_graph("tolerated", saturating_graph())
        .expect("non-strict registration succeeds");
    drop(builder);
}

#[test]
fn zoo_graphs_register_under_strict_verify() {
    let mut builder = ServiceBuilder::new().strict_verify(true);
    for (name, graph) in zoo() {
        builder = builder
            .try_register_graph(name, graph)
            .unwrap_or_else(|e| panic!("{e}"));
    }
    drop(builder);
}

/// N parallel fat→thin branches: each branch inflates 1→8 channels
/// (big intermediate) then reduces back to 1. A wider level schedule
/// keeps more of the thin outputs in flight *on top of* all the fat
/// ones, so peak memory must grow monotonically with width.
#[test]
fn peak_memory_is_monotone_in_schedule_width() {
    let mut b = GraphBuilder::new("branches");
    let x = b.input([1, 4, 4, 1]);
    let mut heads = Vec::new();
    for i in 0..4 {
        let fat = Layer::conv(format!("fat{i}"), 1, 4, 4, 1, 1, 1, 1, 1, 8);
        let thin = Layer::conv(format!("thin{i}"), 1, 4, 4, 1, 1, 1, 1, 8, 1);
        let wf = Tensor4::from_vec([1, 1, 1, 8], vec![1i8; 8]);
        let wt = Tensor4::from_vec([1, 1, 8, 1], vec![1i8; 8]);
        let a = b.accel(x, fat, wf, QParams::from_scale(0.25, 0, true));
        let t = b.accel(a, thin, wt, QParams::from_scale(0.25, 0, true));
        heads.push(t);
    }
    let cat = b.concat(&heads);
    b.output(cat);
    let report = analyze_graph(&b.build().expect("valid topology"));
    assert!(report.is_clean(), "findings: {:?}", report.findings);
    assert_eq!(report.max_accel_width, 4);
    let peaks: Vec<u64> = report.peak_by_width.iter().map(|&(_, p)| p).collect();
    assert_eq!(peaks.len(), 4);
    for pair in peaks.windows(2) {
        assert!(pair[0] <= pair[1], "peaks not monotone: {peaks:?}");
    }
    assert!(
        peaks[peaks.len() - 1] > peaks[0],
        "widest schedule must retain strictly more than width 1: {peaks:?}"
    );
    assert!(
        report.peak_serial_bytes <= peaks[peaks.len() - 1],
        "serial execution cannot out-retain the widest schedule here"
    );
}

#[test]
fn check_report_renders_every_node() {
    let graph = fuse_graph(&tiny_cnn_graph());
    let report = analyze_graph(&graph);
    let rendered = report.render();
    for node in graph.nodes() {
        assert!(
            rendered.contains(&node.op.label()),
            "render missing op '{}'",
            node.op.label()
        );
    }
    assert!(rendered.contains("peak live bytes"));
}

//! Randomized property tests over the dataflow and coordinator
//! invariants (hand-rolled shrinking-free harness — the offline build
//! vendors no proptest; the generator is seeded and prints its seed on
//! failure, so every case is reproducible).

use kraken::arch::{ConfigHeader, KrakenConfig};
use kraken::dataflow::run_conv_loopnest;
use kraken::layers::{KrakenLayerParams, Layer};
use kraken::quant::QParams;
use kraken::sim::{Engine, LayerData};
use kraken::tensor::{conv2d_same_i8, Tensor4};

/// xorshift64 generator for shape sampling.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[(self.next() as usize) % xs.len()]
    }
}

/// Sample a random layer + array config with G ≤ C.
fn sample(rng: &mut Rng) -> (KrakenConfig, Layer) {
    let k = rng.pick(&[1usize, 3, 5, 7]);
    let s = if k == 1 { 1 } else { rng.pick(&[1usize, 2]) };
    let g = k + s - 1;
    let r = rng.range(2, 6);
    let e = rng.range(1, 3);
    let c = g * e + rng.range(0, g - 1).min(2); // sometimes idle cores
    let h = rng.range(k.max(4), 14);
    let w = rng.range(k.max(4), 14);
    let ci = rng.range(1, 6);
    let co = rng.range(1, 9);
    (
        KrakenConfig::new(r, c),
        Layer::conv("prop", 1, h, w, k, k, s, s, ci, co),
    )
}

const CASES: usize = 60;

#[test]
fn prop_engine_bit_exact_and_clock_exact() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed + 1);
        let (cfg, layer) = sample(&mut rng);
        let x = Tensor4::random([1, layer.h, layer.w, layer.ci], seed * 2 + 1);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], seed * 2 + 2);
        let p = KrakenLayerParams::derive(&cfg, &layer);
        let mut engine = Engine::new(cfg.clone(), 8);
        let out = engine.run_layer(&LayerData {
            layer: &layer,
            x: &x,
            k: &k,
            qparams: QParams::identity(),
        });
        let want = conv2d_same_i8(&x, &k, layer.sh, layer.sw);
        assert_eq!(
            out.y_acc, want,
            "seed {seed}: {:?} on {}×{}",
            layer, cfg.r, cfg.c
        );
        assert_eq!(out.clocks, p.q, "seed {seed}: clocks");
    }
}

#[test]
fn prop_loopnest_conserves_macs_and_streams() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed + 1000);
        let (cfg, layer) = sample(&mut rng);
        let x = Tensor4::random([1, layer.h, layer.w, layer.ci], seed * 2 + 1);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], seed * 2 + 2);
        let got = run_conv_loopnest(&cfg, &layer, &x, &k);
        // Valid MACs are exactly eq. (4) — never more, never fewer.
        assert_eq!(got.valid_macs, layer.macs_valid(), "seed {seed}");
        // The engine never reads fewer X̂ words than the raw input needs
        // and reuse means it reads X̂ at most (R+F)·S_H/‐ish × more.
        let p = KrakenLayerParams::derive(&cfg, &layer);
        assert_eq!(
            got.x_words,
            p.t as u64
                * (layer.n * p.l * layer.w * layer.ci * layer.sh * (p.r + p.f)) as u64,
            "seed {seed}: X̂ words"
        );
        // Output stream carries every output pixel at least once.
        let out_pixels = (layer.out_h() * layer.out_w() * layer.co) as u64;
        assert!(got.y_words >= out_pixels, "seed {seed}: Ŷ covers outputs");
    }
}

#[test]
fn prop_header_roundtrip_any_layer() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed + 2000);
        let (cfg, layer) = sample(&mut rng);
        let p = KrakenLayerParams::derive(&cfg, &layer);
        let h = ConfigHeader::for_layer(&layer, &p).expect("encodable");
        let d = ConfigHeader::decode(h.encode()).expect("decodable");
        assert_eq!(h, d, "seed {seed}");
        assert_eq!(d.g(), p.g, "seed {seed}: G from header");
    }
}

#[test]
fn prop_efficiency_bounded_and_monotone_in_rounding() {
    // ℰ_j ∈ (0, 1]; and exact-fit shapes (H multiple of R·S_H, C_o
    // multiple of E·S_W, C multiple of G) dominate their ragged
    // counterparts.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed + 3000);
        let (cfg, layer) = sample(&mut rng);
        let model = kraken::perf::PerfModel {
            cfg: cfg.clone(),
            tech: kraken::perf::Tech::paper_7x96(),
            fc_mem: Default::default(),
        };
        let m = model.layer(&layer);
        assert!(m.efficiency > 0.0 && m.efficiency <= 1.0 + 1e-9, "seed {seed}");
        // Exact-fit variant.
        let p = KrakenLayerParams::derive(&cfg, &layer);
        let mut exact = layer.clone();
        exact.h = p.r * layer.sh * p.l.max(1);
        exact.co = p.e * layer.sw * p.t.max(1);
        let me = model.layer(&exact);
        assert!(
            me.efficiency >= m.efficiency - 1e-9,
            "seed {seed}: exact-fit ℰ {} < ragged ℰ {}",
            me.efficiency,
            m.efficiency
        );
    }
}

#[test]
fn prop_requantize_saturates_and_is_monotone() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed + 4000);
        let shift = rng.range(1, 10) as u32;
        let q = QParams::from_scale(1.0 / (1u64 << shift) as f64, 0, false);
        let mut prev = i8::MIN;
        for acc in (-200_000..200_000).step_by(1777) {
            let v = q.requantize(acc);
            assert!(v >= prev, "seed {seed}: monotone");
            prev = v;
        }
        assert_eq!(q.requantize(i32::MAX), 127);
        assert_eq!(q.requantize(i32::MIN + 1), -128);
    }
}

//! The clock-accurate simulator must agree with §V's closed forms on
//! every derived quantity — clocks (eq. 17), DRAM stream counts
//! (eq. 20) — and with the loop-nest executor and direct-form reference
//! on outputs, across a grid of layer shapes covering every class in
//! Table I plus ragged/rounding corners.

use kraken::arch::KrakenConfig;
use kraken::dataflow::run_conv_loopnest;
use kraken::layers::{KrakenLayerParams, Layer};
use kraken::perf::{FcMemConvention, PerfModel, Tech};
use kraken::quant::QParams;
use kraken::sim::{Engine, LayerData};
use kraken::tensor::{conv2d_same_grouped_i8, conv2d_same_i8, Tensor4};

fn model_for(cfg: &KrakenConfig) -> PerfModel {
    PerfModel { cfg: cfg.clone(), tech: Tech::paper_7x96(), fc_mem: FcMemConvention::Paper }
}

fn cases() -> Vec<(KrakenConfig, Layer)> {
    vec![
        // (R, C) — layer
        (KrakenConfig::new(3, 12), Layer::conv("vgg3x3", 1, 12, 12, 3, 3, 1, 1, 6, 10)),
        (KrakenConfig::new(4, 10), Layer::conv("alex5x1", 1, 11, 11, 5, 5, 1, 1, 4, 6)),
        (KrakenConfig::new(4, 28), Layer::conv("alex11x4", 1, 23, 23, 11, 11, 4, 4, 3, 8)),
        (KrakenConfig::new(3, 16), Layer::conv("res7x2", 1, 14, 14, 7, 7, 2, 2, 3, 4)),
        (KrakenConfig::new(4, 12), Layer::conv("pw1x1", 1, 9, 9, 1, 1, 1, 1, 12, 20)),
        (KrakenConfig::new(2, 6), Layer::conv("tab4", 1, 8, 8, 5, 5, 2, 2, 3, 2)),
        (KrakenConfig::new(3, 9), Layer::conv_grouped("grp", 1, 9, 9, 3, 3, 1, 1, 4, 8, 2)),
        (KrakenConfig::new(3, 9), Layer::conv("batch", 2, 6, 6, 3, 3, 1, 1, 3, 6)),
        (KrakenConfig::new(4, 10), Layer::conv("ragged", 1, 10, 10, 3, 3, 1, 1, 5, 7)),
        (KrakenConfig::new(3, 11), Layer::conv("ragged2", 1, 13, 13, 5, 5, 2, 2, 3, 5)),
        (KrakenConfig::new(5, 13), Layer::conv("odd", 1, 17, 15, 3, 3, 1, 1, 7, 11)),
        // The paper's two implemented configurations at toy layer sizes.
        (KrakenConfig::paper(), Layer::conv("paper7x96", 1, 14, 14, 3, 3, 1, 1, 8, 40)),
        (KrakenConfig::tailored_7x24(), Layer::conv("paper7x24", 1, 14, 14, 3, 3, 1, 1, 8, 16)),
        (KrakenConfig::paper(), Layer::conv("paper_stem", 1, 28, 28, 7, 7, 2, 2, 3, 24)),
    ]
}

#[test]
fn engine_clocks_equal_eq17_everywhere() {
    for (cfg, layer) in cases() {
        let p = KrakenLayerParams::derive(&cfg, &layer);
        let x = Tensor4::random([layer.n, layer.h, layer.w, layer.ci * layer.groups], 11);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], 12);
        let mut engine = Engine::new(cfg, 8);
        let out = engine.run_layer(&LayerData {
            layer: &layer,
            x: &x,
            k: &k,
            qparams: QParams::identity(),
        });
        assert_eq!(out.clocks, p.q, "{}", layer.name);
    }
}

#[test]
fn engine_streams_equal_eq20_everywhere() {
    for (cfg, layer) in cases() {
        let model = model_for(&cfg);
        let m = model.layer(&layer);
        let x = Tensor4::random([layer.n, layer.h, layer.w, layer.ci * layer.groups], 21);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], 22);
        let mut engine = Engine::new(cfg, 8);
        let out = engine.run_layer(&LayerData {
            layer: &layer,
            x: &x,
            k: &k,
            qparams: QParams::identity(),
        });
        assert_eq!(out.counters.dram_x_reads, m.m_x_hat, "{} X̂", layer.name);
        assert_eq!(out.counters.dram_k_reads, m.m_k_hat, "{} K̂", layer.name);
        assert_eq!(out.counters.dram_y_writes, m.m_y_hat, "{} Ŷ", layer.name);
    }
}

#[test]
fn engine_equals_loopnest_equals_reference() {
    for (cfg, layer) in cases() {
        let x = Tensor4::random([layer.n, layer.h, layer.w, layer.ci * layer.groups], 31);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], 32);
        let loopnest = run_conv_loopnest(&cfg, &layer, &x, &k);
        let mut engine = Engine::new(cfg, 8);
        let sim = engine.run_layer(&LayerData {
            layer: &layer,
            x: &x,
            k: &k,
            qparams: QParams::identity(),
        });
        let reference = if layer.groups == 1 {
            conv2d_same_i8(&x, &k, layer.sh, layer.sw)
        } else {
            conv2d_same_grouped_i8(&x, &k, layer.sh, layer.sw, layer.groups)
        };
        assert_eq!(sim.y_acc, reference, "{} sim vs ref", layer.name);
        assert_eq!(loopnest.y, reference, "{} loopnest vs ref", layer.name);
        assert_eq!(sim.clocks, loopnest.clocks, "{} clock agreement", layer.name);
    }
}

#[test]
fn loopnest_valid_macs_equal_eq4() {
    for (cfg, layer) in cases() {
        let x = Tensor4::random([layer.n, layer.h, layer.w, layer.ci * layer.groups], 41);
        let k = Tensor4::random([layer.kh, layer.kw, layer.ci, layer.co], 42);
        let got = run_conv_loopnest(&cfg, &layer, &x, &k);
        assert_eq!(got.valid_macs, layer.macs_valid(), "{}", layer.name);
    }
}

#[test]
fn dense_path_equals_analytical() {
    for (r, c, h, ci, co) in
        [(4usize, 8usize, 10usize, 12usize, 20usize), (7, 96, 7, 256, 96), (3, 5, 9, 17, 11)]
    {
        let cfg = KrakenConfig::new(r, c);
        let layer = Layer::matmul("mm", h, ci, co);
        let p = KrakenLayerParams::derive(&cfg, &layer);
        let m1 = Tensor4::random([1, h, 1, ci], 51);
        let m2 = Tensor4::random([1, 1, ci, co], 52);
        let mut engine = Engine::new(cfg, 8);
        let out = engine.run_dense(&layer, &m1.data, &m2.data, QParams::identity());
        assert_eq!(out.clocks, p.q);
        let want = kraken::tensor::matmul_i8(&m1.data, &m2.data, h, ci, co);
        assert_eq!(out.y_acc.data, want);
    }
}

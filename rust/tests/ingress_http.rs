//! Loopback integration tests for the HTTP ingress: real sockets
//! against a real [`IngressServer`], proving the wire path is a pure
//! transport (bit-identical logits vs direct `submit`), that admission
//! control sheds exactly as specified (`429` queue-full / batch gate,
//! `503` deadline), that malformed traffic maps onto clean 4xx
//! answers, that `/metrics` speaks valid Prometheus text exposition,
//! and that graceful shutdown drains in-flight requests.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use kraken::sync::{thread, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use kraken::arch::KrakenConfig;
use kraken::backend::{Accelerator, Functional, LayerData, LayerOutput};
use kraken::coordinator::{BackendKind, ServiceBuilder};
use kraken::ingress::wire::encode_tensor;
use kraken::ingress::{AdmissionConfig, IngressConfig, IngressServer};
use kraken::layers::LayerKind;
use kraken::metrics::Counters;
use kraken::networks::{tiny_cnn_graph, tiny_mlp_graph, X_SEED};
use kraken::tensor::Tensor4;

// ---------------------------------------------------------------- helpers

fn functional_server(queue_cap: usize, batch_depth_threshold: usize) -> IngressServer {
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(7, 96))
        .backend(BackendKind::Functional)
        .workers(2)
        .register_graph("tiny_cnn", tiny_cnn_graph())
        .register_graph("tiny_mlp", tiny_mlp_graph())
        .build();
    let cfg = IngressConfig {
        handler_threads: 4,
        max_body_bytes: 1 << 20,
        admission: AdmissionConfig {
            queue_cap,
            batch_depth_threshold,
            ..AdmissionConfig::default()
        },
    };
    IngressServer::bind(service, ("127.0.0.1", 0), cfg).expect("bind ephemeral port")
}

/// A backend that blocks inside `run_layer` until its gate opens — lets
/// tests hold a request in flight deterministically.
struct Gated {
    inner: Functional,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Accelerator for Gated {
    fn name(&self) -> String {
        "gated".into()
    }
    fn run_layer(&mut self, data: &LayerData) -> LayerOutput {
        let (open, cv) = &*self.gate;
        let mut open = open.lock().expect("gate");
        while !*open {
            open = cv.wait(open).expect("gate");
        }
        drop(open);
        self.inner.run_layer(data)
    }
    fn counters(&self) -> Counters {
        self.inner.counters()
    }
    fn freq_hz(&self, kind: LayerKind) -> f64 {
        self.inner.freq_hz(kind)
    }
}

fn gated_server(
    queue_cap: usize,
) -> (IngressServer, Arc<(Mutex<bool>, Condvar)>) {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let backend_gate = Arc::clone(&gate);
    let service = ServiceBuilder::new()
        .config(KrakenConfig::new(7, 96))
        .workers(1)
        .register_graph("tiny_cnn", tiny_cnn_graph())
        .build_with(move |_| Gated {
            inner: Functional::new(KrakenConfig::new(7, 96)),
            gate: Arc::clone(&backend_gate),
        });
    let cfg = IngressConfig {
        handler_threads: 4,
        max_body_bytes: 1 << 20,
        admission: AdmissionConfig { queue_cap, ..AdmissionConfig::default() },
    };
    let server = IngressServer::bind(service, ("127.0.0.1", 0), cfg).expect("bind");
    (server, gate)
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (open, cv) = &**gate;
    *open.lock().expect("gate") = true;
    cv.notify_all();
}

fn write_request(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    headers: &[(&str, String)],
    body: &[u8],
    close: bool,
) {
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: loopback\r\nContent-Length: {}\r\n",
        body.len()
    );
    if close {
        head.push_str("Connection: close\r\n");
    }
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).expect("write request head");
    stream.write_all(body).expect("write request body");
}

fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("malformed status line {status_line:?}"))
        .parse()
        .expect("numeric status");
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').expect("header colon");
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let len: usize = headers
        .iter()
        .find(|(name, _)| name == "content-length")
        .map(|(_, value)| value.parse().expect("content-length"))
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).expect("response body");
    (status, headers, body)
}

/// One whole request/response exchange on a fresh connection.
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write_request(&mut stream, method, path, headers, body, true);
    read_response(&mut BufReader::new(stream))
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

fn logits_from_json(body: &[u8]) -> Vec<i32> {
    let text = std::str::from_utf8(body).expect("utf8 body");
    let start = text.find("\"logits\":[").expect("logits field") + "\"logits\":[".len();
    let end = start + text[start..].find(']').expect("closing bracket");
    text[start..end]
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse().expect("integer logit"))
        .collect()
}

fn wait_until(what: &str, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if pred() {
            return;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

// ------------------------------------------------------------------ tests

#[test]
fn http_served_logits_bit_identical_to_direct_submit() {
    let server = functional_server(64, 8);
    let addr = server.local_addr();
    for (model, shape) in
        [("tiny_cnn", [1usize, 28, 28, 3]), ("tiny_mlp", [1, 1, 1, 256])]
    {
        let x = Tensor4::random(shape, X_SEED);
        let want = server.service().infer(model, x.clone()).expect("direct submit");
        let (status, _, body) =
            request(addr, "POST", &format!("/v1/infer/{model}"), &[], &encode_tensor(&x));
        assert_eq!(status, 200, "{model}: {}", String::from_utf8_lossy(&body));
        assert_eq!(
            logits_from_json(&body),
            want.logits,
            "{model}: HTTP-served logits must be bit-identical to direct submit"
        );
    }
    let stats = server.shutdown();
    assert!(stats.completed >= 4, "2 HTTP + 2 direct requests completed");
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let server = functional_server(64, 8);
    let x = Tensor4::random([1, 1, 1, 256], 42);
    let want = server.service().infer("tiny_mlp", x.clone()).expect("direct submit");
    let payload = encode_tensor(&x);

    let mut stream = TcpStream::connect(server.local_addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    for round in 0..2 {
        write_request(&mut stream, "POST", "/v1/infer/tiny_mlp", &[], &payload, false);
        let (status, headers, body) = read_response(&mut reader);
        assert_eq!(status, 200, "round {round}");
        assert_eq!(header(&headers, "connection"), Some("keep-alive"), "round {round}");
        assert_eq!(logits_from_json(&body), want.logits, "round {round}");
    }
    // Third request asks to close; the server must honor it.
    write_request(&mut stream, "GET", "/healthz", &[], b"", true);
    let (status, headers, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(header(&headers, "connection"), Some("close"));
    server.shutdown();
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let server = functional_server(64, 8);
    let addr = server.local_addr();
    let x = Tensor4::random([1, 1, 1, 256], 7);
    let want = server.service().infer("tiny_mlp", x.clone()).expect("direct submit").logits;
    let payload = encode_tensor(&x);

    let clients: Vec<_> = (0..6)
        .map(|_| {
            let payload = payload.clone();
            let want = want.clone();
            thread::spawn(move || {
                for _ in 0..3 {
                    let (status, _, body) =
                        request(addr, "POST", "/v1/infer/tiny_mlp", &[], &payload);
                    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
                    assert_eq!(logits_from_json(&body), want);
                }
            })
        })
        .collect();
    for client in clients {
        client.join().expect("client thread");
    }
    let stats = server.shutdown();
    assert!(stats.completed >= 19, "18 HTTP + 1 direct, got {}", stats.completed);
}

#[test]
fn malformed_requests_map_to_clean_4xx() {
    let server = functional_server(64, 8);
    let addr = server.local_addr();
    let good = encode_tensor(&Tensor4::random([1, 1, 1, 256], 1));

    // Garbage request line.
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(b"garbage\r\n\r\n").expect("write");
    let (status, _, _) = read_response(&mut BufReader::new(stream));
    assert_eq!(status, 400);

    // POST without Content-Length.
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .write_all(b"POST /v1/infer/tiny_mlp HTTP/1.1\r\nHost: x\r\n\r\n")
        .expect("write");
    let (status, _, _) = read_response(&mut BufReader::new(stream));
    assert_eq!(status, 411);

    // Corrupt tensor payload.
    let (status, _, _) = request(addr, "POST", "/v1/infer/tiny_mlp", &[], b"not a tensor");
    assert_eq!(status, 400);

    // Unknown model; unknown route; wrong methods; bad QoS headers.
    let (status, _, _) = request(addr, "POST", "/v1/infer/nope", &[], &good);
    assert_eq!(status, 404);
    let (status, _, _) = request(addr, "GET", "/nope", &[], b"");
    assert_eq!(status, 404);
    let (status, _, _) = request(addr, "GET", "/v1/infer/tiny_mlp", &[], b"");
    assert_eq!(status, 405);
    let (status, _, _) = request(addr, "POST", "/metrics", &[], b"");
    assert_eq!(status, 405);
    let (status, _, _) = request(
        addr,
        "POST",
        "/v1/infer/tiny_mlp",
        &[("x-kraken-lane", "bulk".to_string())],
        &good,
    );
    assert_eq!(status, 400);
    let (status, _, _) = request(
        addr,
        "POST",
        "/v1/infer/tiny_mlp",
        &[("x-kraken-deadline-us", "soon".to_string())],
        &good,
    );
    assert_eq!(status, 400);

    // The server survives all of it.
    let (status, _, _) = request(addr, "GET", "/healthz", &[], b"");
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn queue_cap_overflow_sheds_429_and_is_visible_in_metrics() {
    let (server, gate) = gated_server(1);
    let addr = server.local_addr();
    let payload = encode_tensor(&Tensor4::random([1, 28, 28, 3], X_SEED));

    // Client A: admitted, then parked inside the gated backend.
    let a_payload = payload.clone();
    let a = thread::spawn(move || {
        let (status, _, _) = request(addr, "POST", "/v1/infer/tiny_cnn", &[], &a_payload);
        status
    });
    wait_until("request A to be admitted and in flight", || {
        let (_, _, body) = request(addr, "GET", "/stats", &[], b"");
        String::from_utf8_lossy(&body).contains("\"tiny_cnn\":{\"interactive\":1")
    });

    // Client B: the 1-slot queue is full — shed with 429 + Retry-After.
    let (status, headers, body) = request(addr, "POST", "/v1/infer/tiny_cnn", &[], &payload);
    assert_eq!(status, 429, "{}", String::from_utf8_lossy(&body));
    assert_eq!(header(&headers, "retry-after"), Some("1"));

    // The shed is visible in the Prometheus exposition.
    let (status, _, metrics) = request(addr, "GET", "/metrics", &[], b"");
    assert_eq!(status, 200);
    let metrics = String::from_utf8(metrics).expect("utf8 metrics");
    let shed_line = metrics
        .lines()
        .find(|l| l.starts_with("ingress_shed_queue_full_total{lane=\"interactive\"}"))
        .expect("shed counter exported");
    let shed: u64 =
        shed_line.rsplit(' ').next().expect("value").parse().expect("integer");
    assert!(shed >= 1, "{shed_line}");

    // Release A; it must still complete.
    open_gate(&gate);
    assert_eq!(a.join().expect("client A"), 200);
    server.shutdown();
}

#[test]
fn batch_lane_sheds_on_pool_utilization_while_interactive_serves() {
    // Threshold 0: the pool is always "too deep" for batch traffic.
    let server = functional_server(64, 0);
    let addr = server.local_addr();
    let payload = encode_tensor(&Tensor4::random([1, 1, 1, 256], 3));

    let (status, headers, _) = request(
        addr,
        "POST",
        "/v1/infer/tiny_mlp",
        &[("x-kraken-lane", "batch".to_string())],
        &payload,
    );
    assert_eq!(status, 429, "batch lane must shed at threshold 0");
    assert_eq!(header(&headers, "retry-after"), Some("1"));

    let (status, _, _) = request(
        addr,
        "POST",
        "/v1/infer/tiny_mlp",
        &[("x-kraken-lane", "interactive".to_string())],
        &payload,
    );
    assert_eq!(status, 200, "interactive lane is not utilization-gated");
    server.shutdown();
}

#[test]
fn deadline_expiry_is_503_and_the_worker_survives() {
    let (server, gate) = gated_server(4);
    let addr = server.local_addr();
    let payload = encode_tensor(&Tensor4::random([1, 28, 28, 3], X_SEED));

    // Gate closed: a 50 ms deadline must expire.
    let (status, headers, body) = request(
        addr,
        "POST",
        "/v1/infer/tiny_cnn",
        &[("x-kraken-deadline-us", "50000".to_string())],
        &payload,
    );
    assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
    assert_eq!(header(&headers, "retry-after"), Some("1"));

    let (_, _, metrics) = request(addr, "GET", "/metrics", &[], b"");
    let metrics = String::from_utf8(metrics).expect("utf8 metrics");
    assert!(
        metrics
            .lines()
            .any(|l| l.starts_with("ingress_shed_deadline_total{lane=\"interactive\"}")
                && !l.ends_with(" 0")),
        "deadline shed counter must be exported and non-zero"
    );

    // Open the gate: the worker finishes the stale request (result
    // discarded) and keeps serving fresh ones.
    open_gate(&gate);
    let (status, _, _) = request(addr, "POST", "/v1/infer/tiny_cnn", &[], &payload);
    assert_eq!(status, 200, "worker must survive the dropped late result");
    server.shutdown();
}

#[test]
fn metrics_pass_line_level_prometheus_exposition_check() {
    let server = functional_server(64, 8);
    let addr = server.local_addr();
    // Traffic first, so histograms and counters carry real series.
    let payload = encode_tensor(&Tensor4::random([1, 1, 1, 256], 5));
    let (status, _, _) = request(addr, "POST", "/v1/infer/tiny_mlp", &[], &payload);
    assert_eq!(status, 200);

    let (status, headers, body) = request(addr, "GET", "/metrics", &[], b"");
    assert_eq!(status, 200);
    assert!(
        header(&headers, "content-type").is_some_and(|ct| ct.starts_with("text/plain")),
        "exposition content type"
    );
    let text = String::from_utf8(body).expect("utf8 exposition");
    assert!(text.contains("ingress_admitted_total"), "admission counters exported");
    check_prometheus_exposition(&text);
    server.shutdown();
}

/// Line-level Prometheus text exposition checker: every line is a
/// comment or a `name[{labels}] value` series with a valid metric name,
/// a parseable value, and a preceding `# TYPE` for its family.
fn check_prometheus_exposition(text: &str) {
    fn valid_name(name: &str) {
        assert!(!name.is_empty(), "empty metric name");
        let mut chars = name.chars();
        let first = chars.next().expect("non-empty");
        assert!(
            first.is_ascii_alphabetic() || first == '_' || first == ':',
            "bad metric name start in {name:?}"
        );
        assert!(
            chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name {name:?}"
        );
    }

    let mut typed: HashSet<&str> = HashSet::new();
    let mut series_seen = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().expect("TYPE line carries a name");
            let kind = parts.next().expect("TYPE line carries a kind");
            assert!(
                ["counter", "gauge", "histogram", "summary", "untyped"].contains(&kind),
                "unknown TYPE kind {kind:?} in {line:?}"
            );
            assert!(parts.next().is_none(), "trailing tokens in {line:?}");
            valid_name(name);
            typed.insert(name);
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("series line without a value: {line:?}")
        });
        value.parse::<f64>().unwrap_or_else(|_| {
            panic!("non-numeric sample value {value:?} in {line:?}")
        });
        let base = match series.find('{') {
            Some(i) => {
                assert!(series.ends_with('}'), "unbalanced labels in {line:?}");
                assert!(
                    series[i..].contains("=\""),
                    "labels without quoted values in {line:?}"
                );
                &series[..i]
            }
            None => series,
        };
        valid_name(base);
        // Histogram series attach to their family's TYPE line.
        let family = [base]
            .into_iter()
            .chain(base.strip_suffix("_bucket"))
            .chain(base.strip_suffix("_sum"))
            .chain(base.strip_suffix("_count"))
            .find(|candidate| typed.contains(candidate));
        assert!(family.is_some(), "series {base:?} has no # TYPE line");
        series_seen += 1;
    }
    assert!(series_seen > 0, "exposition must carry at least one series");
}

#[test]
fn graceful_shutdown_drains_the_inflight_request() {
    let (server, gate) = gated_server(4);
    let addr = server.local_addr();
    let payload = encode_tensor(&Tensor4::random([1, 28, 28, 3], X_SEED));

    // Park one request inside the backend.
    let a = thread::spawn(move || {
        let (status, _, _) = request(addr, "POST", "/v1/infer/tiny_cnn", &[], &payload);
        status
    });
    wait_until("request to be admitted and in flight", || {
        let (_, _, body) = request(addr, "GET", "/stats", &[], b"");
        String::from_utf8_lossy(&body).contains("\"tiny_cnn\":{\"interactive\":1")
    });

    // Open the gate shortly after the drain starts, so shutdown really
    // has an in-flight request to wait for.
    let opener = {
        let gate = Arc::clone(&gate);
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(100));
            open_gate(&gate);
        })
    };
    let stats = server.shutdown();
    opener.join().expect("gate opener");

    // The parked client got a real answer, not a reset.
    assert_eq!(a.join().expect("client"), 200);
    assert!(stats.completed >= 1);

    // And the listener is really gone: a fresh exchange must fail.
    let refused = TcpStream::connect(addr)
        .and_then(|mut s| {
            write_request(&mut s, "GET", "/healthz", &[], b"", true);
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).map(|_| buf)
        })
        .map(|buf| buf.is_empty())
        .unwrap_or(true);
    assert!(refused, "post-shutdown connections must not be served");
}

//! The telemetry layer, end to end: histogram quantile edge cases,
//! merge-of-shards equivalence, concurrent recording, per-node trace
//! spans from a pooled graph run (with a hand-rolled JSON well-formed
//! check on the Chrome export — no serde in the offline build), and
//! live `stats_snapshot` consistency under concurrent submitters.

use kraken::sync::atomic::{AtomicBool, Ordering};
use kraken::sync::{thread, Arc};

use kraken::arch::KrakenConfig;
use kraken::backend::Functional;
use kraken::coordinator::{BackendKind, DenseOp, ServiceBuilder};
use kraken::model::{run_graph_on_pool, spawn_node_pool};
use kraken::networks::resnet50_graph_at;
use kraken::networks::tiny_cnn_graph;
use kraken::quant::QParams;
use kraken::telemetry::hist::HistogramCore;
use kraken::telemetry::trace::{self, SpanKind};
use kraken::tensor::Tensor4;

// ---------------------------------------------------------------- hist

#[test]
fn histogram_boundaries_zero_one_max() {
    let h = HistogramCore::new();
    h.record(0);
    h.record(1);
    h.record(u64::MAX);
    let s = h.snapshot();
    assert_eq!(s.count(), 3);
    assert_eq!(s.max(), u64::MAX);
    assert_eq!(s.sum, u64::MAX, "sum must saturate, not wrap");
    // Rank 1 → the zero bucket; rank 2 → the [1,1] bucket; rank 3 →
    // the top bucket clamped to the observed maximum.
    assert_eq!(s.quantile(0.01), 0);
    assert_eq!(s.quantile(0.5), 1);
    assert_eq!(s.quantile(0.99), u64::MAX);
    assert_eq!(s.p999(), u64::MAX);
}

#[test]
fn histogram_quantiles_are_monotone_in_q() {
    let h = HistogramCore::new();
    // Deterministic spread over several orders of magnitude.
    let mut x = 0x2545F4914F6CDD1Du64;
    for _ in 0..10_000 {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        h.record(x % 1_000_000);
    }
    let s = h.snapshot();
    let mut prev = 0u64;
    for i in 0..=1000 {
        let q = i as f64 / 1000.0;
        let v = s.quantile(q);
        assert!(v >= prev, "quantile({q}) = {v} < quantile at previous q = {prev}");
        prev = v;
    }
    assert!(s.quantile(1.0) <= s.max());
    assert!(s.p50() <= s.p95() && s.p95() <= s.p99() && s.p99() <= s.p999());
}

#[test]
fn merged_shard_snapshots_equal_the_whole() {
    // Four per-shard histograms and one histogram that saw every
    // sample: bucket-wise merge of the shard snapshots must equal the
    // whole's snapshot exactly (this is what makes per-worker
    // histograms recombinable).
    let shards: Vec<HistogramCore> = (0..4).map(|_| HistogramCore::new()).collect();
    let whole = HistogramCore::new();
    let mut x = 99u64;
    for i in 0..40_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let v = x % 100_000;
        shards[(i % 4) as usize].record(v);
        whole.record(v);
    }
    let mut merged = shards[0].snapshot();
    for shard in &shards[1..] {
        merged.merge(&shard.snapshot());
    }
    assert_eq!(merged, whole.snapshot());
}

#[test]
fn concurrent_recording_loses_nothing() {
    let h = Arc::new(HistogramCore::new());
    let threads = 8usize;
    let per_thread = 10_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                for i in 0..per_thread {
                    h.record((t as u64 + i) % 7);
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("recorder thread");
    }
    let s = h.snapshot();
    assert_eq!(s.count(), threads as u64 * per_thread);
    let expected_sum: u64 = (0..threads as u64)
        .map(|t| (0..per_thread).map(|i| (t + i) % 7).sum::<u64>())
        .sum();
    assert_eq!(s.sum, expected_sum, "relaxed atomics must still lose no sample");
}

// --------------------------------------------------------------- trace

/// Minimal recursive-descent JSON reader: validates well-formedness
/// (the offline build has no serde). Returns the remaining input on
/// success; panics with context on malformed input.
struct JsonCheck<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> JsonCheck<'a> {
    fn new(s: &'a str) -> Self {
        JsonCheck { s: s.as_bytes(), i: 0 }
    }

    fn peek(&self) -> u8 {
        assert!(self.i < self.s.len(), "unexpected end of JSON at byte {}", self.i);
        self.s[self.i]
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && (self.s[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) {
        self.skip_ws();
        assert_eq!(
            self.peek(),
            c,
            "expected '{}' at byte {}, found '{}'",
            c as char,
            self.i,
            self.peek() as char
        );
        self.i += 1;
    }

    fn value(&mut self) {
        self.skip_ws();
        match self.peek() {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string(),
            b't' => self.literal(b"true"),
            b'f' => self.literal(b"false"),
            b'n' => self.literal(b"null"),
            _ => self.number(),
        }
    }

    fn object(&mut self) {
        self.expect(b'{');
        self.skip_ws();
        if self.peek() == b'}' {
            self.i += 1;
            return;
        }
        loop {
            self.skip_ws();
            self.string();
            self.expect(b':');
            self.value();
            self.skip_ws();
            match self.peek() {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return;
                }
                c => panic!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) {
        self.expect(b'[');
        self.skip_ws();
        if self.peek() == b']' {
            self.i += 1;
            return;
        }
        loop {
            self.value();
            self.skip_ws();
            match self.peek() {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return;
                }
                c => panic!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) {
        self.expect(b'"');
        loop {
            let c = self.peek();
            self.i += 1;
            match c {
                b'"' => return,
                b'\\' => {
                    let esc = self.peek();
                    self.i += 1;
                    assert!(
                        matches!(esc, b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' | b'u'),
                        "bad escape '\\{}' at byte {}",
                        esc as char,
                        self.i
                    );
                    if esc == b'u' {
                        for _ in 0..4 {
                            assert!(
                                (self.peek() as char).is_ascii_hexdigit(),
                                "bad \\u escape at byte {}",
                                self.i
                            );
                            self.i += 1;
                        }
                    }
                }
                c => assert!(c >= 0x20, "unescaped control byte {c:#x} in string"),
            }
        }
    }

    fn number(&mut self) {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        assert!(self.i > start, "expected a JSON value at byte {start}");
    }

    fn literal(&mut self, lit: &[u8]) {
        assert!(
            self.s[self.i..].starts_with(lit),
            "bad literal at byte {}",
            self.i
        );
        self.i += lit.len();
    }

    fn finish(mut self) {
        self.skip_ws();
        assert_eq!(self.i, self.s.len(), "trailing bytes after the JSON document");
    }
}

/// One test owns every interaction with the global span ring (tests in
/// this binary run on parallel threads; splitting this up would race on
/// `enable`/`drain`).
#[test]
fn pooled_resnet_run_records_one_span_per_node() {
    let graph = Arc::new(resnet50_graph_at(32));
    let pool = spawn_node_pool(4, |_| Functional::new(KrakenConfig::paper()));
    let x = Tensor4::random(graph.input_shape(), 11);

    trace::enable(1 << 16);
    let report = run_graph_on_pool(&pool, &graph, &x).expect("traced resnet run");
    trace::disable();
    let spans: Vec<_> = trace::drain()
        .into_iter()
        .filter(|s| s.request == report.request_id)
        .collect();
    pool.shutdown();

    // Exactly one span per graph node, each node covered once.
    let n = graph.nodes().len();
    assert_eq!(spans.len(), n, "one span per node");
    let mut seen = vec![false; n];
    for s in &spans {
        assert!(!seen[s.node], "node {} recorded twice", s.node);
        seen[s.node] = true;
    }
    assert!(seen.iter().all(|&b| b), "every node must be covered");

    // Kinds match the graph: accel nodes from pool workers (or the
    // driver when reclaimed inline), host ops always on the driver.
    let by_node: Vec<&trace::SpanEvent> = {
        let mut v: Vec<&trace::SpanEvent> = spans.iter().collect();
        v.sort_by_key(|s| s.node);
        v
    };
    for (node, span) in graph.nodes().iter().zip(&by_node) {
        let is_accel = matches!(node.op, kraken::model::NodeOp::Accel(_));
        match span.kind {
            SpanKind::Accel => assert!(is_accel, "accel span on host node {}", span.node),
            SpanKind::Host => {
                assert!(!is_accel, "host span on accel node {}", span.node);
                assert_eq!(span.worker, trace::DRIVER_WORKER, "host ops run on the driver");
            }
        }
    }

    // Dependency nesting: a node's span cannot start before every
    // input's span has ended (floor arithmetic keeps this exact:
    // ⌊a⌋ + ⌊b⌋ ≤ ⌊a + b⌋ and ends precede dependent starts in real
    // time, across threads, because Instant is monotonic).
    for (i, node) in graph.nodes().iter().enumerate() {
        for input in &node.inputs {
            let (si, sj) = (by_node[i], by_node[input.0]);
            assert!(
                si.start_us >= sj.start_us + sj.dur_us,
                "node {} (start {}) began before its input {} ended ({} + {})",
                i,
                si.start_us,
                input.0,
                sj.start_us,
                sj.dur_us
            );
        }
    }

    // With >1 worker the accel spans must actually spread across the
    // pool rows (ResNet-50's projection blocks have parallel branches).
    let workers: std::collections::BTreeSet<usize> =
        spans.iter().filter(|s| s.kind == SpanKind::Accel).map(|s| s.worker).collect();
    assert!(!workers.is_empty());

    // The Chrome export must be a single well-formed JSON document with
    // one "X" event per span (hand-parsed; no serde offline).
    let json = trace::chrome_trace_json(&spans);
    let mut check = JsonCheck::new(&json);
    check.value();
    check.finish();
    assert_eq!(
        json.matches("\"ph\":\"X\"").count(),
        spans.len(),
        "one complete event per span"
    );
    let distinct_workers: std::collections::BTreeSet<usize> =
        spans.iter().map(|s| s.worker).collect();
    assert_eq!(
        json.matches("\"ph\":\"M\"").count(),
        distinct_workers.len(),
        "one thread_name metadata event per timeline row"
    );
    assert!(json.contains("\"args\":{\"name\":\"driver\"}"), "driver row must be named");
}

// ------------------------------------------------------------- service

#[test]
fn stats_snapshot_is_consistent_under_concurrent_submits() {
    let (ci, co) = (16usize, 8usize);
    let service = Arc::new(
        ServiceBuilder::new()
            .config(KrakenConfig::new(7, 96))
            .backend(BackendKind::Functional)
            .workers(2)
            .batch_capacity(4)
            .register_graph("tiny_cnn", tiny_cnn_graph())
            .register_dense(
                "fc",
                DenseOp::new(
                    "fc",
                    ci,
                    co,
                    Tensor4::random([1, 1, ci, co], 5).data,
                    QParams::identity(),
                ),
            )
            .build(),
    );

    let submitters = 4usize;
    let graphs_each = 3usize;
    let rows_each = 8usize;
    let done = Arc::new(AtomicBool::new(false));

    // A watcher hammers the live snapshot while submitters run: every
    // snapshot it takes must satisfy the counter invariant, and the
    // completed count must never go backwards.
    let watcher = {
        let service = Arc::clone(&service);
        let done = Arc::clone(&done);
        thread::spawn(move || {
            let mut last_completed = 0u64;
            let mut taken = 0usize;
            while !done.load(Ordering::Acquire) {
                let snap = service.stats_snapshot();
                assert_eq!(
                    snap.stats.completed,
                    snap.stats.per_model.values().sum::<u64>(),
                    "completed must equal the per-model sum in every live snapshot"
                );
                assert!(
                    snap.stats.completed >= last_completed,
                    "completed went backwards"
                );
                let lat_total: u64 =
                    snap.latency.values().map(|l| l.total.count()).sum();
                assert!(
                    lat_total <= snap.stats.completed,
                    "latency samples ({lat_total}) cannot exceed completions"
                );
                last_completed = snap.stats.completed;
                taken += 1;
                thread::yield_now();
            }
            taken
        })
    };

    let handles: Vec<_> = (0..submitters)
        .map(|t| {
            let service = Arc::clone(&service);
            thread::spawn(move || {
                for g in 0..graphs_each {
                    let x = Tensor4::random([1, 28, 28, 3], (t * 100 + g) as u64);
                    service.submit("tiny_cnn", x).wait().expect("graph served");
                }
                let tickets: Vec<_> = (0..rows_each)
                    .map(|r| {
                        let row = Tensor4::random([1, 1, 1, ci], (t * 1000 + r) as u64).data;
                        service.submit("fc", row)
                    })
                    .collect();
                for ticket in tickets {
                    ticket.wait().expect("row served");
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("submitter");
    }
    service.flush();
    done.store(true, Ordering::Release);
    let snapshots_taken = watcher.join().expect("watcher");
    assert!(snapshots_taken > 0);

    let graphs = (submitters * graphs_each) as u64;
    let rows = (submitters * rows_each) as u64;
    let snap = service.stats_snapshot();
    assert_eq!(snap.stats.completed, graphs + rows);
    assert_eq!(snap.stats.per_model["tiny_cnn"], graphs);
    assert_eq!(snap.stats.per_model["fc"], rows);
    assert_eq!(snap.stats.failed, 0);
    assert_eq!(snap.latency["tiny_cnn"].total.count(), graphs);
    assert_eq!(snap.latency["fc"].total.count(), rows);
    assert!(snap.latency["tiny_cnn"].total.max() > 0, "a real run takes > 1 µs");

    // The exposition agrees with the snapshot, and carries the
    // process-global GEMM pack-cache counters after functional runs.
    let text = service.render_prometheus();
    assert!(
        text.contains(&format!("kraken_requests_completed_total{{model=\"tiny_cnn\"}} {graphs}")),
        "{text}"
    );
    assert!(text.contains("# TYPE kraken_request_latency_us histogram"), "{text}");
    assert!(text.contains("kraken_gemm_pack_cache_hits_total"), "{text}");

    // Quiesced: shutdown totals must match the last live snapshot, and
    // pool jobs (graphs + dense flushes) must account for every worker
    // cell increment.
    let service = Arc::try_unwrap(service).ok().expect("all clones dropped");
    let stats = service.shutdown();
    assert_eq!(stats.completed, snap.stats.completed);
    assert_eq!(stats.per_model, snap.stats.per_model);
    assert_eq!(stats.dense_rows, rows);
    assert_eq!(
        stats.per_worker.iter().map(|w| w.completed).sum::<u64>(),
        graphs + stats.dense_flushes,
        "worker cells must count one job per graph request and per dense flush"
    );
}

//! End-to-end integration over the PJRT runtime: the AOT-lowered
//! JAX/Pallas artifacts (L1+L2) must agree **bit-exactly** with the
//! clock-accurate simulator (L3's engine) and the direct-form Rust
//! reference, on every (K, S) shape class of Table I and on the full
//! TinyCNN forward.
//!
//! Requires `make artifacts` (the Makefile runs it before tests) and a
//! build with the native PJRT bridge (`RUSTFLAGS="--cfg pjrt_native"`
//! with the `xla` crate vendored) — without it the whole file compiles
//! to nothing, and `backend_equivalence.rs` carries the offline
//! cross-backend verification instead.
#![cfg(pjrt_native)]

use std::path::Path;

use kraken::arch::KrakenConfig;
use kraken::layers::Layer;
use kraken::model::run_graph;
use kraken::networks::tiny_cnn_graph;
use kraken::quant::QParams;
use kraken::runtime::{ArtifactKind, GoldenRunner};
use kraken::sim::{Engine, LayerData};
use kraken::tensor::{conv2d_same_grouped_i8, conv2d_same_i8, Tensor4};

fn runner() -> GoldenRunner {
    GoldenRunner::new(Path::new("artifacts"))
        .expect("artifacts/ missing or stale — run `make artifacts`")
}

#[test]
fn conv_goldens_match_simulator_bit_exactly() {
    let runner = runner();
    let (r, c) = (runner.runtime.manifest.r, runner.runtime.manifest.c);
    let specs: Vec<String> = runner
        .runtime
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.kind == ArtifactKind::Conv)
        .map(|a| a.name.clone())
        .collect();
    assert!(specs.len() >= 6, "expected all conv shape classes");
    for name in specs {
        let case = runner.run(&name).expect("golden run");
        let s = case.spec.clone();
        let ci_per_group = s.k_shape[2];
        let layer = Layer::conv_grouped(
            s.name.clone(),
            s.x_shape[0],
            s.x_shape[1],
            s.x_shape[2],
            s.k_shape[0],
            s.k_shape[1],
            s.sh,
            s.sw,
            ci_per_group,
            s.k_shape[3],
            s.groups,
        );
        // Simulator.
        let mut engine = Engine::new(KrakenConfig::new(r, c), 8);
        let out = engine.run_layer(&LayerData {
            layer: &layer,
            x: &case.x,
            k: &case.k,
            qparams: QParams::identity(),
        });
        assert_eq!(
            out.y_acc.data, case.y,
            "{name}: simulator disagrees with JAX/Pallas artifact"
        );
        // Direct-form reference.
        let reference = if s.groups == 1 {
            conv2d_same_i8(&case.x, &case.k, s.sh, s.sw)
        } else {
            conv2d_same_grouped_i8(&case.x, &case.k, s.sh, s.sw, s.groups)
        };
        assert_eq!(reference.data, case.y, "{name}: reference disagrees with artifact");
    }
}

#[test]
fn matmul_golden_matches_simulator() {
    let runner = runner();
    let case = runner.run("matmul").expect("matmul golden");
    let s = case.spec.clone();
    let layer = Layer::matmul("mm", s.x_shape[0], s.x_shape[1], s.k_shape[1]);
    let mut engine = Engine::new(
        KrakenConfig::new(runner.runtime.manifest.r, runner.runtime.manifest.c),
        8,
    );
    let out = engine.run_dense(&layer, &case.x.data, &case.k.data, QParams::identity());
    // Engine output is [1, H, 1, Co] row-major = [H, Co].
    assert_eq!(out.y_acc.data, case.y, "matmul: simulator vs artifact");
}

#[test]
fn tiny_cnn_logits_match_graph_executor() {
    let runner = runner();
    let (x, _weights, golden_logits) = runner.run_tiny_cnn().expect("tiny_cnn artifact");
    let mut engine = Engine::new(KrakenConfig::new(7, 96), 8);
    let report =
        run_graph(&mut engine, &tiny_cnn_graph(), &x).expect("artifact input shape matches");
    assert_eq!(
        report.logits, golden_logits,
        "full-network logits: graph executor+simulator vs JAX/Pallas artifact"
    );
}

#[test]
fn xorshift_cross_language() {
    // Pinned against python/tests/test_model.py::test_xorshift_reference_values.
    let t = Tensor4::random([1, 1, 1, 10], 7);
    assert_eq!(t.data, vec![122, 2, -64, -100, -80, 40, -45, 126, 112, 70]);
    let t = Tensor4::random([1, 1, 1, 10], 42);
    assert_eq!(t.data, vec![-43, 106, 90, -97, 110, 39, 68, -91, 56, -109]);
}

#[test]
fn runtime_reports_cpu_platform() {
    let runner = runner();
    let platform = runner.runtime.platform().to_lowercase();
    assert!(
        platform.contains("cpu") || platform.contains("host"),
        "platform={platform}"
    );
}

//! Partitioned-vs-unpartitioned equivalence: splitting one layer
//! across a pool of chips must be invisible in the math.
//!
//! For every layer of TinyCNN and TinyMLP and for full-size AlexNet
//! conv1, at P ∈ {2, 4}: the [`PartitionedPool`]'s gathered outputs
//! (`y_acc` and `y_q`) are bit-exact against a single backend, the
//! merged makespan equals the planner's eq. (17) prediction (and never
//! exceeds the unsplit clocks), and the summed DRAM words equal the
//! planner's eq. (20) prediction — exactly the unsplit words plus the
//! reported replication overhead (input broadcast / halo rows).

use kraken::arch::KrakenConfig;
use kraken::backend::{Accelerator, Functional, LayerData, LayerOutput};
use kraken::coordinator::{BackendKind, ServiceBuilder};
use kraken::layers::Layer;
use kraken::networks::{tiny_cnn, tiny_cnn_graph, tiny_mlp, Network};
use kraken::partition::{plan_layer, PartitionedPool};
use kraken::quant::QParams;
use kraken::sim::Engine;
use kraken::tensor::Tensor4;

const SEED: u64 = 31_000;

/// Run every layer of `net` on one functional backend and on a
/// P-shard partitioned pool, asserting full equivalence per layer.
fn assert_net_equivalent(net: &Network, shards: usize) {
    let cfg = KrakenConfig::paper();
    let mut whole = Functional::new(cfg.clone());
    let mut pool =
        PartitionedPool::spawn(cfg.clone(), shards, |_| Functional::new(KrakenConfig::paper()));
    let base_outs: Vec<LayerOutput> = net.run_layers(&mut whole, SEED);
    let pool_outs: Vec<LayerOutput> = net.run_layers(&mut pool, SEED);
    for (j, layer) in net.layers.iter().enumerate() {
        let (base, split) = (&base_outs[j], &pool_outs[j]);
        let plan = plan_layer(&cfg, layer, shards);
        assert_eq!(split.y_acc, base.y_acc, "{} P={shards}: y_acc", layer.name);
        assert_eq!(split.y_q, base.y_q, "{} P={shards}: y_q", layer.name);
        assert_eq!(
            split.clocks, plan.predicted_clocks,
            "{} P={shards}: makespan vs plan",
            layer.name
        );
        assert!(
            split.clocks <= base.clocks,
            "{} P={shards}: partitioning must never slow a layer down",
            layer.name
        );
        assert_eq!(
            split.counters.dram_total(),
            plan.predicted_dram_words,
            "{} P={shards}: summed DRAM words vs plan",
            layer.name
        );
        assert_eq!(
            split.counters.dram_total(),
            base.counters.dram_total() + plan.replication_overhead_words(),
            "{} P={shards}: DRAM words = unsplit + reported overhead",
            layer.name
        );
    }
}

#[test]
fn tiny_cnn_partitioned_bit_exact_p2_p4() {
    for shards in [2, 4] {
        assert_net_equivalent(&tiny_cnn(), shards);
    }
}

#[test]
fn tiny_mlp_partitioned_bit_exact_p2_p4() {
    for shards in [2, 4] {
        assert_net_equivalent(&tiny_mlp(), shards);
    }
}

#[test]
fn alexnet_conv1_partitioned_bit_exact_p2_p4() {
    // The large-kernel strided class (11×11, S = 4): the awkward
    // halo-alignment case for row splits and a 4-way channel split.
    let cfg = KrakenConfig::paper();
    let layer = Layer::conv("alex_conv1", 1, 227, 227, 11, 11, 4, 4, 3, 96);
    let (x, k) = Network::seeded_layer_tensors(&layer, SEED + 100);
    let data = LayerData { layer: &layer, x: &x, k: &k, qparams: QParams::identity() };
    let mut whole = Functional::new(cfg.clone());
    let base = whole.run_layer(&data);
    for shards in [2usize, 4] {
        let mut pool = PartitionedPool::spawn(cfg.clone(), shards, |_| {
            Functional::new(KrakenConfig::paper())
        });
        let split = pool.run_layer(&data);
        let plan = plan_layer(&cfg, &layer, shards);
        assert_eq!(split.y_acc, base.y_acc, "P={shards}");
        assert_eq!(split.y_q, base.y_q, "P={shards}");
        assert_eq!(split.clocks, plan.predicted_clocks, "P={shards}");
        assert_eq!(split.counters.dram_total(), plan.predicted_dram_words, "P={shards}");
        // co = 96 over E·S_W = 24: T divides evenly at P ∈ {2, 4}, so
        // the channel split is DRAM-neutral and cuts T proportionally.
        assert_eq!(plan.replication_overhead_words(), 0, "P={shards}");
        assert_eq!(split.clocks * shards as u64, base.clocks, "P={shards}");
    }
}

#[test]
fn engine_shards_match_functional_shards() {
    // The pool is backend-agnostic: cycle-accurate engines as shards
    // produce the same merged output and makespan as functional shards.
    let cfg = KrakenConfig::paper();
    let layer = Layer::conv("c", 1, 14, 14, 3, 3, 1, 1, 8, 64);
    let (x, k) = Network::seeded_layer_tensors(&layer, SEED + 200);
    let data = LayerData { layer: &layer, x: &x, k: &k, qparams: QParams::identity() };
    let mut engines =
        PartitionedPool::spawn(cfg.clone(), 2, |_| Engine::new(KrakenConfig::paper(), 8));
    let mut functionals =
        PartitionedPool::spawn(cfg, 2, |_| Functional::new(KrakenConfig::paper()));
    let a = engines.run_layer(&data);
    let b = functionals.run_layer(&data);
    assert_eq!(a.y_acc, b.y_acc);
    assert_eq!(a.y_q, b.y_q);
    assert_eq!(a.clocks, b.clocks);
    assert_eq!(a.counters.dram_total(), b.counters.dram_total());
}

#[test]
fn partitioned_service_serves_bit_identical_outputs() {
    // The acceptance bar for the serving front-end: a KrakenService
    // configured with partition(P) must serve exactly what an
    // unpartitioned one serves — the scatter/gather is invisible
    // through the whole builder → registry → ticket path.
    // (The batching+partitioning composition test lives in
    // tests/service_api.rs::batching_then_partitioning_compose.)
    let build = |partition: usize| {
        ServiceBuilder::new()
            .config(KrakenConfig::paper())
            .backend(BackendKind::Functional)
            .workers(1)
            .partition(partition)
            .register_graph("tiny_cnn", tiny_cnn_graph())
            .build()
    };
    let whole = build(1);
    let inputs: Vec<Tensor4<i8>> =
        (0..3).map(|i| Tensor4::random([1, 28, 28, 3], SEED + 300 + i)).collect();
    let want: Vec<Vec<i32>> = whole
        .submit_batch("tiny_cnn", inputs.clone())
        .into_iter()
        .map(|t| t.wait().expect("unpartitioned response").logits)
        .collect();
    whole.shutdown();
    for partition in [2usize, 4] {
        let split = build(partition);
        let got: Vec<Vec<i32>> = split
            .submit_batch("tiny_cnn", inputs.clone())
            .into_iter()
            .map(|t| t.wait().expect("partitioned response").logits)
            .collect();
        assert_eq!(got, want, "partition({partition}) must be bit-identical");
        let stats = split.shutdown();
        assert_eq!(stats.completed, 3);
    }
}
